//! Lightweight workload migration (paper §IV-A).
//!
//! A straggler migrates FFN contraction columns (ffl slices) to the
//! normal tasks.  Under column-wise TP the input x and the LN params are
//! already replicated, so only the *weights* of the migrated slice move —
//! `w1[:, mig]` and `w2[mig, :]` — via tree **broadcast**; receivers run
//! the self-contained `mlp_mig_*` slice executables; their y/dx partials
//! fold into the branch all-reduce (**reduce-merging**) and only the small
//! compact weight-grads travel back.  The conventional
//! **scatter-gather** alternative sends per-receiver weight slices flat
//! and gathers full `[b,s,hs]` partials back to the straggler — the
//! redundant double transfer Table I measures.
//!
//! Column assignment uses the paper's virtual renumbering (§IV-B,
//! `cluster::mig_range`); slices are chunked to the compiled `kb` buckets
//! and zero-padded (exactness argument in python/compile/model.py).

use crate::cluster::mig_range;
use crate::runtime::manifest::Manifest;

/// One receiver's work-list for a straggler's layer: chunks into the
/// migrated index array, each mapped to a compiled kb bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct Chunk {
    /// offset into the migrated set
    pub start: usize,
    /// actual columns in this chunk (≤ kb)
    pub len: usize,
    /// compiled bucket the chunk is padded to
    pub kb: usize,
}

#[derive(Debug, Clone)]
pub struct ReceiverWork {
    pub rank: usize,
    pub chunks: Vec<Chunk>,
}

impl ReceiverWork {
    /// Actual migrated columns this receiver computes (sum of chunk
    /// lens, not the padded kb buckets).
    pub fn cols(&self) -> usize {
        self.chunks.iter().map(|c| c.len).sum()
    }
}

/// Per-layer migration plan for one straggler (same for every block —
/// layers have identical FFN shapes, mirroring Eq. (1)'s uniform γ).
#[derive(Debug, Clone)]
pub struct MigPlan {
    pub straggler: usize,
    /// migrated ffl indices (ascending), |migrated| = l_mig
    pub migrated: Vec<u32>,
    /// kept ffl indices for the straggler's own (g00, b2) executables
    pub kept: Vec<u32>,
    /// the straggler-side mlp bucket name for idx2
    pub kept_bucket: String,
    pub receivers: Vec<ReceiverWork>,
}

impl MigPlan {
    pub fn l_mig(&self) -> usize {
        self.migrated.len()
    }

    /// Bytes of weight broadcast per layer per direction-independent
    /// setup: w1 cols + w2 rows of the migrated slice.
    pub fn weight_bytes(&self, hs: usize) -> usize {
        2 * hs * self.l_mig() * 4
    }

    /// Columns landing on `rank` (0 when it is not a receiver) — the
    /// per-receiver input to the memory-headroom check in the balancer.
    pub fn cols_for(&self, rank: usize) -> usize {
        self.receivers
            .iter()
            .find(|rw| rw.rank == rank)
            .map_or(0, ReceiverWork::cols)
    }
}

/// Build a migration plan.
///
/// `remove_frac` of the FFN contraction is removed from the straggler
/// (rounded UP to a compiled straggler-side bucket); of the removed
/// columns, up to `mig_frac_of_removed` are *migrated* (computed exactly
/// by receivers) and the rest are left to be pruned+imputed by resizing —
/// the SEMI three-way split.  Pure MIG passes 1.0, pure resizing has no
/// plan at all.
///
/// `kept_pref` is a full priority ranking (keep-first); the kept set is
/// its prefix, and the *highest-priority* removed columns are migrated
/// (exactness where it matters most).  `None` keeps the identity prefix.
pub fn plan(
    manifest: &Manifest,
    straggler: usize,
    remove_frac: f64,
    mig_frac_of_removed: f64,
    kept_pref: Option<&[u32]>,
) -> Option<MigPlan> {
    let m = &manifest.model;
    if remove_frac <= 0.0 || mig_frac_of_removed <= 0.0 {
        return None;
    }
    // straggler-side executable needs keep_ffl ∈ buckets (b1 = g00):
    let bucket = manifest.bucket_for_gamma(remove_frac);
    if bucket.gamma <= 0.0 {
        return None;
    }
    let keep_ffl = bucket.keep_ffl;
    let l_removed = m.ffl - keep_ffl;
    let l_mig = ((l_removed as f64) * mig_frac_of_removed.min(1.0)).round() as usize;
    if l_mig == 0 {
        return None;
    }

    let (kept, migrated) = match kept_pref {
        Some(pref) => {
            debug_assert_eq!(pref.len(), m.ffl, "kept_pref must rank all indices");
            let mut kept: Vec<u32> = pref[..keep_ffl].to_vec();
            let mut migrated: Vec<u32> = pref[keep_ffl..keep_ffl + l_mig].to_vec();
            kept.sort_unstable();
            migrated.sort_unstable();
            (kept, migrated)
        }
        None => (
            (0..keep_ffl as u32).collect(),
            (keep_ffl as u32..(keep_ffl + l_mig) as u32).collect(),
        ),
    };

    // distribute migrated columns across normal ranks (virtual renumber)
    let max_kb = *manifest.mig_buckets.last()?;
    let mut receivers = Vec::new();
    for r in (0..m.e).filter(|&r| r != straggler) {
        let (s, t) = mig_range(r, straggler, m.e, l_mig);
        if s == t {
            continue;
        }
        let mut chunks = Vec::new();
        let mut pos = s;
        while pos < t {
            let len = (t - pos).min(max_kb);
            let kb = manifest.mig_bucket_for(len).unwrap_or(max_kb);
            chunks.push(Chunk { start: pos, len, kb });
            pos += len;
        }
        receivers.push(ReceiverWork { rank: r, chunks });
    }
    Some(MigPlan {
        straggler,
        migrated,
        kept,
        kept_bucket: bucket.name.clone(),
        receivers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "model": {"name":"t","hs":32,"depth":2,"heads":4,"e":4,"bs":2,
                    "classes":10,"seq":17,"seq0":16,"pd":48,"hsl":8,"hl":1,
                    "hd":8,"ffl":32,"params_total":0,"params_per_worker":0},
          "buckets": [
            {"name":"g00","gamma":0,"keep_hs":32,"keep_ffl":32},
            {"name":"g25","gamma":0.25,"keep_hs":24,"keep_ffl":24},
            {"name":"g50","gamma":0.5,"keep_hs":16,"keep_ffl":16},
            {"name":"g88","gamma":0.875,"keep_hs":8,"keep_ffl":8}
          ],
          "mig_buckets": [8, 16],
          "executables": []
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn zero_demand_no_plan() {
        let m = manifest();
        assert!(plan(&m, 0, 0.0, 1.0, None).is_none());
        assert!(plan(&m, 0, -1.0, 1.0, None).is_none());
        assert!(plan(&m, 0, 0.5, 0.0, None).is_none());
    }

    #[test]
    fn kept_plus_migrated_partition_ffl() {
        let m = manifest();
        let p = plan(&m, 1, 0.5, 1.0, None).unwrap();
        assert_eq!(p.kept.len() + p.migrated.len(), 32);
        let mut all: Vec<u32> = p.kept.iter().chain(p.migrated.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..32).collect::<Vec<u32>>());
        assert_eq!(p.kept_bucket, "g50");
    }

    #[test]
    fn receiver_chunks_cover_migrated_exactly() {
        let m = manifest();
        for frac in [0.25, 0.5, 0.875] {
            let p = plan(&m, 0, frac, 1.0, None).unwrap();
            let mut covered = vec![false; p.l_mig()];
            for rw in &p.receivers {
                assert_ne!(rw.rank, 0);
                for c in &rw.chunks {
                    assert!(c.len <= c.kb, "chunk exceeds bucket");
                    for i in c.start..c.start + c.len {
                        assert!(!covered[i], "overlap");
                        covered[i] = true;
                    }
                }
            }
            assert!(covered.iter().all(|&b| b), "gap at frac={frac}");
        }
    }

    #[test]
    fn chunks_respect_bucket_sizes() {
        let m = manifest();
        let p = plan(&m, 0, 0.875, 1.0, None).unwrap(); // l_mig = 24, 3 receivers
        for rw in &p.receivers {
            for c in &rw.chunks {
                assert!(m.mig_buckets.contains(&c.kb));
            }
        }
    }

    #[test]
    fn priority_preference_respected() {
        let m = manifest();
        // prefer keeping odd indices (pref = keep-order ranking)
        let pref: Vec<u32> = (0..32u32)
            .map(|i| if i < 16 { i * 2 + 1 } else { (i - 16) * 2 })
            .collect();
        let p = plan(&m, 0, 0.5, 1.0, Some(&pref)).unwrap();
        assert!(p.kept.iter().all(|&i| i % 2 == 1));
        assert!(p.migrated.iter().all(|&i| i % 2 == 0));
    }

    #[test]
    fn receiver_cols_partition_l_mig() {
        let m = manifest();
        let p = plan(&m, 0, 0.875, 1.0, None).unwrap();
        let total: usize = p.receivers.iter().map(ReceiverWork::cols).sum();
        assert_eq!(total, p.l_mig());
        for rw in &p.receivers {
            assert_eq!(p.cols_for(rw.rank), rw.cols());
        }
        assert_eq!(p.cols_for(0), 0, "the straggler receives nothing");
        assert_eq!(p.cols_for(99), 0, "non-receivers report zero");
    }

    #[test]
    fn weight_bytes_scale_with_l_mig() {
        let m = manifest();
        let p = plan(&m, 0, 0.5, 1.0, None).unwrap();
        assert_eq!(p.weight_bytes(32), 2 * 32 * 16 * 4);

        // three-way split: only half the removed columns migrate
        let p = plan(&m, 0, 0.5, 0.5, None).unwrap();
        assert_eq!(p.migrated.len(), 8);
        assert_eq!(p.kept.len(), 16);
    }
}
