//! Per-rank / per-phase attribution: the `flextp trace report` table and
//! the per-cell phase-time summaries sweeps embed in
//! `BENCH_scenarios.json`.
//!
//! One aggregation path serves both the in-memory tracer (end of a
//! traced `flextp train`) and a parsed JSONL file (`flextp trace report
//! <trace.jsonl>`), so the CLI and the sweep columns can never disagree.
//!
//! The headline number is the observability analogue of the paper's
//! T_i/M_i monitor: per epoch, pick the rank with the most χ-induced
//! compute slowdown, measure its *excess* compute SimClock over the
//! fastest rank, and report what fraction of that excess the trace
//! explains as χ-slowed compute (the matching peer-side all-reduce wait
//! corroborates it from the other side of the barrier).

use std::collections::BTreeMap;

use crate::util::json::{obj, Json};
use crate::util::table::TextTable;

use super::{Kind, Span};

/// Per-rank SimClock totals within one epoch.
#[derive(Debug, Clone, Default)]
pub struct RankAgg {
    pub rank: u32,
    /// all compute charged to the rank's clock (χ-skewed phases,
    /// replicated embed/head, migration slices, recompute surcharge)
    pub compute_s: f64,
    /// the χ-induced share of `compute_s`: Σ dur·(1−1/χ)
    pub chi_excess_s: f64,
    /// activation-recompute surcharge (also counted in `compute_s`)
    pub recompute_s: f64,
    /// pre-collective barrier waits
    pub wait_s: f64,
    /// collective transfer time (branch all-reduces + detection gathers)
    pub xfer_s: f64,
    /// balancer replan overhead Ω₁
    pub replan_s: f64,
    /// migration weight-movement collectives
    pub mig_s: f64,
    /// bytes moved through collectives on this rank
    pub comm_bytes: u64,
    /// churn/memory/checkpoint instants observed
    pub events: u32,
}

/// One epoch's attribution: per-rank totals plus the straggler verdict.
#[derive(Debug, Clone)]
pub struct EpochAttr {
    pub epoch: u32,
    pub ranks: Vec<RankAgg>,
    /// rank with the largest χ-induced slowdown (None if χ never rose)
    pub straggler: Option<u32>,
    /// straggler compute excess over the fastest rank (s)
    pub excess_s: f64,
    /// the straggler's χ-induced slowdown (s)
    pub chi_slowdown_s: f64,
    /// mean all-reduce wait across the other ranks (s) — the barrier-side
    /// image of the same straggle
    pub peer_wait_s: f64,
    /// % of `excess_s` explained by χ-slowed compute (100 when there is
    /// no excess to explain)
    pub attributed_pct: f64,
}

/// Whole-trace attribution (what `flextp trace report` renders).
#[derive(Debug, Clone)]
pub struct Attribution {
    pub epochs: Vec<EpochAttr>,
    pub spans: usize,
}

impl Attribution {
    /// Aggregate any span stream (tracer-merged or JSONL-parsed).
    pub fn from_spans<'a, I: IntoIterator<Item = &'a Span>>(spans: I) -> Attribution {
        let mut by_epoch: BTreeMap<u32, BTreeMap<u32, RankAgg>> = BTreeMap::new();
        let mut n = 0usize;
        for s in spans {
            n += 1;
            let agg = by_epoch
                .entry(s.epoch)
                .or_default()
                .entry(s.rank)
                .or_insert_with(|| RankAgg { rank: s.rank, ..RankAgg::default() });
            match s.kind {
                Kind::Compute => {
                    agg.compute_s += s.dur;
                    agg.chi_excess_s += s.chi_excess_s();
                }
                Kind::Recompute => {
                    agg.compute_s += s.dur;
                    agg.recompute_s += s.dur;
                }
                Kind::CommWait => agg.wait_s += s.dur,
                Kind::CommXfer | Kind::Detect => {
                    agg.xfer_s += s.dur;
                    agg.comm_bytes += s.bytes;
                }
                Kind::Replan => agg.replan_s += s.dur,
                Kind::Migration => {
                    agg.mig_s += s.dur;
                    agg.comm_bytes += s.bytes;
                }
                Kind::Churn | Kind::Mem | Kind::Checkpoint => agg.events += 1,
            }
        }
        let epochs = by_epoch
            .into_iter()
            .map(|(epoch, ranks)| {
                let ranks: Vec<RankAgg> = ranks.into_values().collect();
                EpochAttr::judge(epoch, ranks)
            })
            .collect();
        Attribution { epochs, spans: n }
    }

    /// Render the per-epoch tables + straggler verdicts.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if self.epochs.is_empty() {
            out.push_str("trace report: no spans (was the run traced with --trace?)\n");
            return out;
        }
        for ep in &self.epochs {
            let mut t = TextTable::new(
                &format!("trace report — epoch {}", ep.epoch),
                &[
                    "rank", "compute_s", "chi_excess_s", "wait_s", "xfer_s", "replan_s",
                    "mig_s", "recompute_s", "comm_MB", "events",
                ],
            );
            for r in &ep.ranks {
                t.row(&[
                    r.rank.to_string(),
                    format!("{:.4}", r.compute_s),
                    format!("{:.4}", r.chi_excess_s),
                    format!("{:.4}", r.wait_s),
                    format!("{:.4}", r.xfer_s),
                    format!("{:.4}", r.replan_s),
                    format!("{:.4}", r.mig_s),
                    format!("{:.4}", r.recompute_s),
                    format!("{:.2}", r.comm_bytes as f64 / 1e6),
                    r.events.to_string(),
                ]);
            }
            out.push_str(&t.render());
            out.push_str(&ep.verdict());
            out.push('\n');
        }
        out
    }

    /// Straggler verdict of the epoch with the most excess to explain
    /// (what sweeps and the acceptance check consume).
    pub fn worst_epoch(&self) -> Option<&EpochAttr> {
        self.epochs
            .iter()
            .filter(|e| e.straggler.is_some())
            .max_by(|a, b| a.excess_s.total_cmp(&b.excess_s))
    }

    /// Whole-run phase totals, summed over epochs and ranks — the
    /// per-cell summary sweeps embed as a `phases` object.
    pub fn phase_totals(&self) -> PhaseTotals {
        let mut p = PhaseTotals::default();
        for ep in &self.epochs {
            for r in &ep.ranks {
                p.compute_s += r.compute_s;
                p.chi_excess_s += r.chi_excess_s;
                p.wait_s += r.wait_s;
                p.xfer_s += r.xfer_s;
                p.replan_s += r.replan_s;
                p.mig_s += r.mig_s;
                p.recompute_s += r.recompute_s;
                p.comm_bytes += r.comm_bytes;
                p.events += r.events as u64;
            }
        }
        if let Some(w) = self.worst_epoch() {
            p.straggler = w.straggler;
            p.attributed_pct = w.attributed_pct;
        }
        p.spans = self.spans as u64;
        p
    }
}

impl EpochAttr {
    fn judge(epoch: u32, ranks: Vec<RankAgg>) -> EpochAttr {
        let straggler = ranks
            .iter()
            .max_by(|a, b| a.chi_excess_s.total_cmp(&b.chi_excess_s))
            .filter(|r| r.chi_excess_s > 0.0)
            .map(|r| r.rank);
        let (mut excess_s, mut chi_slowdown_s, mut peer_wait_s, mut attributed_pct) =
            (0.0, 0.0, 0.0, 100.0);
        if let Some(s) = straggler {
            let sagg = ranks.iter().find(|r| r.rank == s).expect("straggler agg");
            let min_compute = ranks
                .iter()
                .map(|r| r.compute_s)
                .fold(f64::INFINITY, f64::min);
            excess_s = sagg.compute_s - min_compute;
            chi_slowdown_s = sagg.chi_excess_s;
            let peers: Vec<&RankAgg> = ranks.iter().filter(|r| r.rank != s).collect();
            if !peers.is_empty() {
                peer_wait_s = peers.iter().map(|r| r.wait_s).sum::<f64>() / peers.len() as f64;
            }
            attributed_pct = if excess_s > 1e-12 {
                100.0 * chi_slowdown_s.min(excess_s) / excess_s
            } else {
                100.0
            };
        }
        EpochAttr {
            epoch,
            ranks,
            straggler,
            excess_s,
            chi_slowdown_s,
            peer_wait_s,
            attributed_pct,
        }
    }

    /// One-line cause naming for the epoch.
    pub fn verdict(&self) -> String {
        match self.straggler {
            Some(s) => format!(
                "epoch {}: straggler rank {} — excess compute {:.4}s, {:.1}% attributed to \
                 chi-slowed compute ({:.4}s); peers absorbed it as {:.4}s mean all-reduce wait\n",
                self.epoch, s, self.excess_s, self.attributed_pct, self.chi_slowdown_s,
                self.peer_wait_s
            ),
            None => format!("epoch {}: no injected straggler observed (chi stayed 1.0)\n", self.epoch),
        }
    }
}

/// Whole-run phase-time breakdown, serialized into sweep cells.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseTotals {
    pub compute_s: f64,
    pub chi_excess_s: f64,
    pub wait_s: f64,
    pub xfer_s: f64,
    pub replan_s: f64,
    pub mig_s: f64,
    pub recompute_s: f64,
    pub comm_bytes: u64,
    pub events: u64,
    pub spans: u64,
    pub straggler: Option<u32>,
    pub attributed_pct: f64,
}

impl PhaseTotals {
    pub fn to_json(&self) -> Json {
        obj([
            ("compute_s", Json::Num(self.compute_s)),
            ("chi_excess_s", Json::Num(self.chi_excess_s)),
            ("wait_s", Json::Num(self.wait_s)),
            ("xfer_s", Json::Num(self.xfer_s)),
            ("replan_s", Json::Num(self.replan_s)),
            ("mig_s", Json::Num(self.mig_s)),
            ("recompute_s", Json::Num(self.recompute_s)),
            ("comm_bytes", Json::from(self.comm_bytes as usize)),
            ("events", Json::from(self.events as usize)),
            ("spans", Json::from(self.spans as usize)),
            (
                "straggler",
                match self.straggler {
                    Some(r) => Json::from(r as usize),
                    None => Json::Null,
                },
            ),
            ("attributed_pct", Json::Num(self.attributed_pct)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(rank: u32, epoch: u32, kind: Kind, dur: f64, chi: f64) -> Span {
        Span {
            rank,
            epoch,
            giter: 0,
            kind,
            label: "x".to_string(),
            layer: -1,
            t0: 0.0,
            dur,
            bytes: 0,
            chi,
            wall_us: 0,
        }
    }

    #[test]
    fn attribution_names_the_chi_straggler() {
        // rank 1 does the same base work (0.1s) at chi=6 -> 0.6s skewed;
        // rank 0 waits out the difference at the barrier.
        let spans = vec![
            span(0, 0, Kind::Compute, 0.1, 1.0),
            span(1, 0, Kind::Compute, 0.6, 6.0),
            span(0, 0, Kind::CommWait, 0.5, 1.0),
            span(0, 0, Kind::CommXfer, 0.01, 1.0),
            span(1, 0, Kind::CommXfer, 0.01, 1.0),
        ];
        let a = Attribution::from_spans(spans.iter());
        assert_eq!(a.epochs.len(), 1);
        let ep = &a.epochs[0];
        assert_eq!(ep.straggler, Some(1));
        assert!((ep.excess_s - 0.5).abs() < 1e-12);
        assert!((ep.chi_slowdown_s - 0.5).abs() < 1e-12);
        assert!(ep.attributed_pct > 99.9);
        assert!((ep.peer_wait_s - 0.5).abs() < 1e-12);
        assert!(ep.verdict().contains("straggler rank 1"));
    }

    #[test]
    fn homogeneous_trace_has_no_straggler() {
        let spans = vec![
            span(0, 0, Kind::Compute, 0.1, 1.0),
            span(1, 0, Kind::Compute, 0.1, 1.0),
        ];
        let a = Attribution::from_spans(spans.iter());
        assert_eq!(a.epochs[0].straggler, None);
        assert!(a.epochs[0].verdict().contains("no injected straggler"));
    }

    #[test]
    fn recompute_counts_as_compute_but_tracked() {
        let spans = vec![
            span(0, 0, Kind::Compute, 0.2, 1.0),
            span(0, 0, Kind::Recompute, 0.1, 1.0),
        ];
        let a = Attribution::from_spans(spans.iter());
        let r = &a.epochs[0].ranks[0];
        assert!((r.compute_s - 0.3).abs() < 1e-12);
        assert!((r.recompute_s - 0.1).abs() < 1e-12);
    }

    #[test]
    fn phase_totals_sum_epochs_and_serialize() {
        let spans = vec![
            span(0, 0, Kind::Compute, 0.1, 1.0),
            span(1, 0, Kind::Compute, 0.6, 6.0),
            span(0, 1, Kind::Replan, 0.02, 1.0),
            span(0, 1, Kind::Churn, 0.0, 1.0),
        ];
        let a = Attribution::from_spans(spans.iter());
        let p = a.phase_totals();
        assert!((p.compute_s - 0.7).abs() < 1e-12);
        assert!((p.replan_s - 0.02).abs() < 1e-12);
        assert_eq!(p.events, 1);
        assert_eq!(p.spans, 4);
        assert_eq!(p.straggler, Some(1));
        let j = p.to_json();
        assert_eq!(j.get("straggler").unwrap().usize().unwrap(), 1);
        assert!(j.get("attributed_pct").unwrap().num().unwrap() > 99.0);
    }

    #[test]
    fn render_has_tables_and_verdicts() {
        let spans = vec![
            span(0, 0, Kind::Compute, 0.1, 1.0),
            span(1, 0, Kind::Compute, 0.6, 6.0),
        ];
        let a = Attribution::from_spans(spans.iter());
        let r = a.render();
        assert!(r.contains("trace report — epoch 0"));
        assert!(r.contains("chi_excess_s"));
        assert!(r.contains("straggler rank 1"));
    }
}
