//! Trace serialization: newline-JSONL (scripting) and Chrome/Perfetto
//! `trace.json` (load via https://ui.perfetto.dev or chrome://tracing).
//!
//! Both exports walk [`Tracer::merged`], so file order is the
//! deterministic (t0, rank, seq) merge order.  JSONL can be emitted with
//! or without the `wall_us` field: determinism suites compare the
//! without-wall form byte-for-byte across `--threads`.

use std::path::{Path, PathBuf};

use crate::util::json::{obj, Json};

use super::{Kind, Span, TraceError, Tracer};

/// One span as a JSONL object (alphabetical keys via the BTreeMap
/// emitter, so emission is deterministic).
pub fn span_to_json(s: &Span, with_wall: bool) -> Json {
    let mut pairs = vec![
        ("rank", Json::from(s.rank as usize)),
        ("epoch", Json::from(s.epoch as usize)),
        ("giter", Json::from(s.giter as usize)),
        ("kind", Json::from(s.kind.as_str())),
        ("label", Json::from(s.label.as_str())),
        ("layer", Json::Num(s.layer as f64)),
        ("t0", Json::Num(s.t0)),
        ("dur", Json::Num(s.dur)),
        ("bytes", Json::from(s.bytes as usize)),
        ("chi", Json::Num(s.chi)),
    ];
    if with_wall {
        pairs.push(("wall_us", Json::from(s.wall_us as usize)));
    }
    obj(pairs)
}

/// Parse one JSONL line back into a [`Span`] (`wall_us` optional — the
/// without-wall export form parses to `wall_us == 0`).
pub fn span_from_json(v: &Json) -> anyhow::Result<Span> {
    let kind_s = v.get("kind")?.str()?;
    let kind = Kind::parse(kind_s)
        .ok_or_else(|| anyhow::anyhow!("unknown span kind '{kind_s}'"))?;
    Ok(Span {
        rank: v.get("rank")?.usize()? as u32,
        epoch: v.get("epoch")?.usize()? as u32,
        giter: v.get("giter")?.usize()? as u64,
        kind,
        label: v.get("label")?.str()?.to_string(),
        layer: v.get("layer")?.num()? as i32,
        t0: v.get("t0")?.num()?,
        dur: v.get("dur")?.num()?,
        bytes: v.get("bytes")?.usize()? as u64,
        chi: v.get("chi")?.num()?,
        wall_us: match v.opt("wall_us") {
            Some(w) => w.usize()? as u64,
            None => 0,
        },
    })
}

/// Merged spans as newline-JSONL text.
pub fn to_jsonl(tracer: &Tracer, with_wall: bool) -> String {
    let mut out = String::new();
    for s in tracer.merged() {
        out.push_str(&span_to_json(s, with_wall).to_string());
        out.push('\n');
    }
    out
}

/// Parse a JSONL trace file's text (written by [`to_jsonl`]).
pub fn parse_jsonl(text: &str, path: &Path) -> Result<Vec<Span>, TraceError> {
    let mut spans = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| TraceError::Malformed {
            path: path.to_path_buf(),
            reason: format!("line {}: {e}", i + 1),
        })?;
        spans.push(span_from_json(&v).map_err(|e| TraceError::Malformed {
            path: path.to_path_buf(),
            reason: format!("line {}: {e}", i + 1),
        })?);
    }
    Ok(spans)
}

/// Merged spans as a Chrome/Perfetto trace: complete events (`ph:"X"`)
/// on pid 0, one tid lane per rank, timestamps in µs of SimClock.
pub fn to_perfetto(tracer: &Tracer) -> String {
    let mut events: Vec<Json> = Vec::new();
    // thread_name metadata so Perfetto labels lanes "rank N"
    for r in 0..tracer.lanes() {
        events.push(obj([
            ("ph", Json::from("M")),
            ("name", Json::from("thread_name")),
            ("pid", Json::from(0usize)),
            ("tid", Json::from(r)),
            ("args", obj([("name", Json::from(format!("rank {r}")))])),
        ]));
    }
    for s in tracer.merged() {
        events.push(obj([
            ("ph", Json::from("X")),
            ("name", Json::from(s.label.as_str())),
            ("cat", Json::from(s.kind.as_str())),
            ("ts", Json::Num(s.t0 * 1e6)),
            ("dur", Json::Num(s.dur * 1e6)),
            ("pid", Json::from(0usize)),
            ("tid", Json::from(s.rank as usize)),
            (
                "args",
                obj([
                    ("epoch", Json::from(s.epoch as usize)),
                    ("giter", Json::from(s.giter as usize)),
                    ("layer", Json::Num(s.layer as f64)),
                    ("bytes", Json::from(s.bytes as usize)),
                    ("chi", Json::Num(s.chi)),
                    ("wall_us", Json::from(s.wall_us as usize)),
                ]),
            ),
        ]));
    }
    obj([
        ("displayTimeUnit", Json::from("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
    .to_string()
}

/// Write `trace.jsonl` + `trace.json` (Perfetto) under `dir`.  Returns
/// the two paths; any I/O failure maps to the typed
/// [`TraceError::Unwritable`] so callers warn instead of panicking.
pub fn write_outputs(tracer: &Tracer, dir: &Path) -> Result<(PathBuf, PathBuf), TraceError> {
    super::validate_out(dir)?;
    let unwritable = |p: &Path, e: std::io::Error| TraceError::Unwritable {
        path: p.to_path_buf(),
        reason: e.to_string(),
    };
    let jsonl = dir.join("trace.jsonl");
    std::fs::write(&jsonl, to_jsonl(tracer, true)).map_err(|e| unwritable(&jsonl, e))?;
    let perfetto = dir.join("trace.json");
    std::fs::write(&perfetto, to_perfetto(tracer)).map_err(|e| unwritable(&perfetto, e))?;
    Ok((jsonl, perfetto))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tracer() -> Tracer {
        let mut tr = Tracer::new(2, 64, true, false);
        tr.begin_iter(0, 0, 0, 0.0, &[1.0, 6.0]);
        tr.compute(0, Kind::Compute, "attn_fwd", 0, 0.1, 0.1, 1.0);
        tr.compute(1, Kind::Compute, "attn_fwd", 0, 0.6, 0.6, 6.0);
        tr.comm_wait(0, "attn_fwd", 0.1, 0.5);
        tr.comm_xfer(0, Kind::CommXfer, "attn_fwd", 0.6, 0.01, 1024);
        tr.comm_xfer(1, Kind::CommXfer, "attn_fwd", 0.6, 0.01, 1024);
        tr.event(0, Kind::Churn, "transition:2->1", 0, 0, 0.61, 0.0, 0);
        tr
    }

    #[test]
    fn jsonl_roundtrips_bitwise() {
        let tr = sample_tracer();
        let text = to_jsonl(&tr, true);
        let spans = parse_jsonl(&text, Path::new("mem")).unwrap();
        let merged = tr.merged();
        assert_eq!(spans.len(), merged.len());
        for (a, b) in spans.iter().zip(merged.iter()) {
            assert!(a.sim_eq(b), "{a:?} != {b:?}");
            assert_eq!(a.wall_us, b.wall_us);
        }
    }

    #[test]
    fn without_wall_form_has_no_wall_field() {
        let tr = sample_tracer();
        let text = to_jsonl(&tr, false);
        assert!(!text.contains("wall_us"));
        // and still parses (wall defaults to 0)
        let spans = parse_jsonl(&text, Path::new("mem")).unwrap();
        assert!(spans.iter().all(|s| s.wall_us == 0));
    }

    #[test]
    fn perfetto_shape() {
        let tr = sample_tracer();
        let v = Json::parse(&to_perfetto(&tr)).unwrap();
        let events = v.get("traceEvents").unwrap().arr().unwrap();
        // 2 thread_name metadata + 7 spans
        assert_eq!(events.len(), 2 + tr.merged().len());
        let first_span = events
            .iter()
            .find(|e| e.get("ph").unwrap().str().unwrap() == "X")
            .unwrap();
        assert_eq!(first_span.get("pid").unwrap().usize().unwrap(), 0);
        assert!(first_span.get("ts").unwrap().num().unwrap() >= 0.0);
        assert!(first_span.opt("cat").is_some());
    }

    #[test]
    fn malformed_jsonl_is_typed() {
        let err = parse_jsonl("{not json}\n", Path::new("bad.jsonl")).unwrap_err();
        assert!(matches!(err, TraceError::Malformed { .. }));
        let err2 = parse_jsonl("{\"kind\":\"nope\"}\n", Path::new("bad.jsonl")).unwrap_err();
        assert!(err2.to_string().contains("Malformed"));
    }
}
