//! Zero-observer-effect tracing (DESIGN.md §17).
//!
//! A per-rank span recorder for the simulated cluster: every SimClock
//! charge the trainer or the collectives make can mirror itself as a
//! [`Span`] — phase compute (with the rank's χ), the wait-vs-transfer
//! split of each collective, balancer replans (Ω₁), migration traffic,
//! checkpoint/churn transitions, and memory events.  Spans land in
//! per-rank ring buffers and are merged on the coordinator in a
//! deterministic order for export (Perfetto `trace.json`, newline-JSONL)
//! and for the `flextp trace report` attribution table.
//!
//! # The zero-observer contract
//!
//! Tracing must never perturb the simulation: with `--trace` on or off,
//! at `--threads 1` or N, on either transport, losses / SimClocks /
//! `CommStats` stay **bitwise identical** (`tests/trace_determinism.rs`).
//! Three properties make that true by construction:
//!
//! * the recorder only *reads* clocks — a span records `now(r)` and the
//!   already-computed charge, it never advances anything;
//! * recording happens exclusively on the coordinator thread, inside the
//!   same rank-order replay loops that do the clock accounting, so the
//!   event stream (and every f64 accumulation) is identical at any
//!   `--threads`;
//! * wall-clock timestamps live in a single non-deterministic field
//!   ([`Span::wall_us`]) that every parity diff and [`Span::sim_eq`]
//!   exclude.
//!
//! "Lock-free-enough": the rings sit behind one `Mutex` shared by the
//! trainer and `Comm`, but only the coordinator thread ever takes it —
//! pool workers compute, they never trace — so the lock is uncontended
//! by design rather than by a lock-free structure.
//!
//! The `--timeline` per-iteration sampler is a *view* over this same
//! event stream: [`Tracer::begin_iter`]/[`Tracer::end_iter`] accumulate
//! the per-rank compute charges (in the exact order the clocks do) and
//! synthesize the [`IterSample`]s that used to be built ad hoc in the
//! trainer.

pub mod export;
pub mod report;

use std::collections::VecDeque;
use std::path::PathBuf;

use crate::metrics::IterSample;

/// Typed tracing fault (satellite: an unwritable `--trace-out` is a
/// warning, never a panic mid-epoch).
#[derive(Debug)]
pub enum TraceError {
    /// `--trace-out` cannot be created or written.
    Unwritable { path: PathBuf, reason: String },
    /// a trace file handed to `flextp trace report` does not parse
    Malformed { path: PathBuf, reason: String },
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Unwritable { path, reason } => {
                write!(f, "TraceError::Unwritable: --trace-out '{}' is not writable ({reason})",
                       path.display())
            }
            TraceError::Malformed { path, reason } => {
                write!(f, "TraceError::Malformed: trace file '{}' did not parse ({reason})",
                       path.display())
            }
        }
    }
}

impl std::error::Error for TraceError {}

/// Span category — what kind of SimClock time (or instant event) this is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// phase compute charged to the rank's clock (χ-skewed GEMMs,
    /// replicated embed/head, migration receiver slices)
    Compute,
    /// activation-checkpointing surcharge (DESIGN.md §16)
    Recompute,
    /// pre-collective barrier wait (the straggler tax on the fast ranks)
    CommWait,
    /// the collective's own α-β transfer time
    CommXfer,
    /// detection statistics collectives (T_i all-gathers)
    Detect,
    /// balancer replan overhead Ω₁
    Replan,
    /// migration weight-movement collectives (bcast/scatter/gather)
    Migration,
    /// worker churn: join/leave/fail events and E→E' transitions
    Churn,
    /// memory events: squeezes, OOM evictions
    Mem,
    /// a `.flexckpt` snapshot write
    Checkpoint,
}

impl Kind {
    pub fn as_str(&self) -> &'static str {
        match self {
            Kind::Compute => "compute",
            Kind::Recompute => "recompute",
            Kind::CommWait => "comm_wait",
            Kind::CommXfer => "comm_xfer",
            Kind::Detect => "detect",
            Kind::Replan => "replan",
            Kind::Migration => "migration",
            Kind::Churn => "churn",
            Kind::Mem => "mem",
            Kind::Checkpoint => "checkpoint",
        }
    }

    pub fn parse(s: &str) -> Option<Kind> {
        Some(match s {
            "compute" => Kind::Compute,
            "recompute" => Kind::Recompute,
            "comm_wait" => Kind::CommWait,
            "comm_xfer" => Kind::CommXfer,
            "detect" => Kind::Detect,
            "replan" => Kind::Replan,
            "migration" => Kind::Migration,
            "churn" => Kind::Churn,
            "mem" => Kind::Mem,
            "checkpoint" => Kind::Checkpoint,
            _ => return None,
        })
    }
}

/// One recorded interval (or instant, `dur == 0`) on a rank's timeline.
///
/// `t0`/`dur` are **SimClock** seconds, cumulative across epochs (the
/// tracer adds the per-epoch frontier so exported timelines don't fold
/// back on themselves at epoch resets).  `wall_us` is the only
/// non-deterministic field — microseconds of real time since the first
/// span — and is excluded from every determinism comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub rank: u32,
    pub epoch: u32,
    pub giter: u64,
    pub kind: Kind,
    /// phase / strategy-action label ("attn_fwd", "mig_slice",
    /// "transition:4->2", "oom-evict:r2", …)
    pub label: String,
    /// transformer block index, -1 when not layer-scoped
    pub layer: i32,
    /// SimClock start, cumulative across epochs (seconds)
    pub t0: f64,
    /// SimClock duration (seconds; 0 for instant events)
    pub dur: f64,
    /// counter: payload bytes for comm spans, capacity/need bytes for
    /// memory events, 0 otherwise
    pub bytes: u64,
    /// the rank's χ for compute spans (1.0 elsewhere) — `dur·(1−1/χ)`
    /// is the span's injected-slowdown share
    pub chi: f64,
    /// wall-clock microseconds since tracing started — the ONE
    /// non-deterministic field, excluded from parity diffs
    pub wall_us: u64,
}

impl Span {
    /// Deterministic-field equality: everything except `wall_us`.
    pub fn sim_eq(&self, o: &Span) -> bool {
        self.rank == o.rank
            && self.epoch == o.epoch
            && self.giter == o.giter
            && self.kind == o.kind
            && self.label == o.label
            && self.layer == o.layer
            && self.t0.to_bits() == o.t0.to_bits()
            && self.dur.to_bits() == o.dur.to_bits()
            && self.bytes == o.bytes
            && self.chi.to_bits() == o.chi.to_bits()
    }

    /// χ-induced slowdown inside this span: the extra seconds versus the
    /// same work at χ=1 (`dur` already includes the skew, so the base
    /// work is `dur/χ`).
    pub fn chi_excess_s(&self) -> f64 {
        if self.chi > 1.0 { self.dur * (1.0 - 1.0 / self.chi) } else { 0.0 }
    }
}

/// Fixed-capacity per-rank span buffer: oldest spans drop first, with a
/// drop counter so truncation is never silent.
#[derive(Debug)]
struct RankRing {
    cap: usize,
    next_seq: u64,
    dropped: u64,
    buf: VecDeque<(u64, Span)>,
}

impl RankRing {
    fn new(cap: usize) -> RankRing {
        RankRing { cap: cap.max(1), next_seq: 0, dropped: 0, buf: VecDeque::new() }
    }

    fn push(&mut self, span: Span) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back((self.next_seq, span));
        self.next_seq += 1;
    }
}

/// The recorder.  Owned by the trainer (shared with `Comm` behind
/// `Arc<Mutex<..>>`); all methods are cheap no-ops while inactive
/// (warmup) or when the relevant view (`--trace` spans, `--timeline`
/// samples) is off.
#[derive(Debug)]
pub struct Tracer {
    /// record full spans into the rings (`--trace`)
    spans_on: bool,
    /// synthesize per-iteration [`IterSample`]s (`--timeline`)
    timeline_on: bool,
    /// false while warmup_and_pretest's untimed iteration runs
    active: bool,
    ring_cap: usize,
    rings: Vec<RankRing>,
    /// cumulative SimClock of completed epochs (clocks reset per epoch;
    /// spans record `base + raw` so exported time is monotone)
    clock_base: f64,
    cur_giter: u64,
    cur_epoch: u32,
    cur_iter: u32,
    in_iter: bool,
    iter_start: f64,
    /// per-rank compute accumulated this iteration, in the exact f64
    /// order the SimClocks accumulate `iter_compute` — what makes the
    /// folded `--timeline` bitwise-identical to the pre-trace sampler
    iter_t: Vec<f64>,
    iter_chi: Vec<f64>,
    wall0: std::time::Instant,
}

impl Tracer {
    pub fn new(e: usize, ring_cap: usize, spans_on: bool, timeline_on: bool) -> Tracer {
        Tracer {
            spans_on,
            timeline_on,
            active: true,
            ring_cap,
            rings: (0..e).map(|_| RankRing::new(ring_cap)).collect(),
            clock_base: 0.0,
            cur_giter: 0,
            cur_epoch: 0,
            cur_iter: 0,
            in_iter: false,
            iter_start: 0.0,
            iter_t: vec![0.0; e],
            iter_chi: vec![1.0; e],
            wall0: std::time::Instant::now(),
        }
    }

    /// Suppress/resume recording (the trainer parks the tracer during
    /// the untimed warmup iteration, exactly like χ accounting).
    pub fn set_active(&mut self, on: bool) {
        self.active = on;
    }

    /// Should `Comm` bother building wait/transfer spans?
    pub fn comm_enabled(&self) -> bool {
        self.active && self.spans_on
    }

    /// Grow the per-rank rings to at least `e` lanes (elastic re-shard /
    /// rejoin).  Shrinking never discards history: a departed rank's
    /// lane stays exportable.
    pub fn ensure_ranks(&mut self, e: usize) {
        while self.rings.len() < e {
            self.rings.push(RankRing::new(self.ring_cap));
        }
    }

    /// Fold a completed epoch's SimClock frontier into the cumulative
    /// base — called right before the trainer resets the clocks.
    pub fn epoch_rollover(&mut self, frontier: f64) {
        self.clock_base += frontier;
    }

    /// Start an iteration: snapshot χ and the clock frontier, reset the
    /// per-rank compute accumulators (sized to the current group).
    pub fn begin_iter(&mut self, giter: u64, epoch: u32, iter: u32, frontier: f64, chi: &[f64]) {
        if !self.active {
            return;
        }
        self.cur_giter = giter;
        self.cur_epoch = epoch;
        self.cur_iter = iter;
        self.in_iter = true;
        self.iter_start = frontier;
        self.iter_t.clear();
        self.iter_t.resize(chi.len(), 0.0);
        self.iter_chi.clear();
        self.iter_chi.extend_from_slice(chi);
        self.ensure_ranks(chi.len());
    }

    /// Close the iteration; under `--timeline` returns the synthesized
    /// sample (the view the run report serializes).
    pub fn end_iter(&mut self, frontier: f64, replanned: bool) -> Option<IterSample> {
        if !(self.active && self.in_iter) {
            return None;
        }
        self.in_iter = false;
        if !self.timeline_on {
            return None;
        }
        Some(IterSample {
            giter: self.cur_giter,
            epoch: self.cur_epoch as usize,
            iter: self.cur_iter as usize,
            chi: self.iter_chi.clone(),
            t_iter: self.iter_t.clone(),
            rt_iter_s: frontier - self.iter_start,
            replanned,
        })
    }

    fn wall_us(&self) -> u64 {
        self.wall0.elapsed().as_micros() as u64
    }

    fn push(&mut self, rank: usize, span: Span) {
        if rank < self.rings.len() {
            self.rings[rank].push(span);
        }
    }

    /// Mirror a compute charge: `dur` is the already-skewed SimClock
    /// seconds just advanced on `rank` (so `t_end_raw - dur` is the span
    /// start), `chi` the injector's multiplier for it.  Also feeds the
    /// `--timeline` accumulator — in charge order, so the folded sampler
    /// stays bitwise equal to summing the clock's own `iter_compute`.
    pub fn compute(
        &mut self,
        rank: usize,
        kind: Kind,
        label: &'static str,
        layer: i32,
        t_end_raw: f64,
        dur: f64,
        chi: f64,
    ) {
        if !self.active {
            return;
        }
        if rank < self.iter_t.len() {
            self.iter_t[rank] += dur;
        }
        if !self.spans_on {
            return;
        }
        let span = Span {
            rank: rank as u32,
            epoch: self.cur_epoch,
            giter: self.cur_giter,
            kind,
            label: label.to_string(),
            layer,
            t0: self.clock_base + (t_end_raw - dur),
            dur,
            bytes: 0,
            chi,
            wall_us: self.wall_us(),
        };
        self.push(rank, span);
    }

    /// Pre-collective barrier wait on `rank` (skipped for zero waits —
    /// the frontier rank by definition waits for nobody).
    pub fn comm_wait(&mut self, rank: usize, label: &str, t_raw: f64, dur: f64) {
        if !self.comm_enabled() {
            return;
        }
        let span = Span {
            rank: rank as u32,
            epoch: self.cur_epoch,
            giter: self.cur_giter,
            kind: Kind::CommWait,
            label: label.to_string(),
            layer: -1,
            t0: self.clock_base + t_raw,
            dur,
            bytes: 0,
            chi: 1.0,
            wall_us: self.wall_us(),
        };
        self.push(rank, span);
    }

    /// The collective's transfer phase on `rank`: `bytes` is the
    /// payload, `kind` distinguishes branch all-reduces ([`Kind::CommXfer`]),
    /// detection gathers ([`Kind::Detect`]) and migration weight movement
    /// ([`Kind::Migration`]).
    pub fn comm_xfer(
        &mut self,
        rank: usize,
        kind: Kind,
        label: &str,
        t_raw: f64,
        dur: f64,
        bytes: u64,
    ) {
        if !self.comm_enabled() {
            return;
        }
        let span = Span {
            rank: rank as u32,
            epoch: self.cur_epoch,
            giter: self.cur_giter,
            kind,
            label: label.to_string(),
            layer: -1,
            t0: self.clock_base + t_raw,
            dur,
            bytes,
            chi: 1.0,
            wall_us: self.wall_us(),
        };
        self.push(rank, span);
    }

    /// A control event with an explicit cursor: replans (Ω₁, `dur > 0`),
    /// churn/memory/checkpoint instants (`dur == 0`).  `t_end_raw` is
    /// the rank's clock after any charge, like [`Tracer::compute`].
    #[allow(clippy::too_many_arguments)]
    pub fn event(
        &mut self,
        rank: usize,
        kind: Kind,
        label: &str,
        giter: u64,
        epoch: u32,
        t_end_raw: f64,
        dur: f64,
        bytes: u64,
    ) {
        if !(self.active && self.spans_on) {
            return;
        }
        let span = Span {
            rank: rank as u32,
            epoch,
            giter,
            kind,
            label: label.to_string(),
            layer: -1,
            t0: self.clock_base + (t_end_raw - dur),
            dur,
            bytes,
            chi: 1.0,
            wall_us: self.wall_us(),
        };
        self.push(rank, span);
    }

    /// Were full spans requested (`--trace`)?
    pub fn spans_on(&self) -> bool {
        self.spans_on
    }

    /// Total spans dropped to ring capacity across all ranks (0 in any
    /// normally-sized run; reported so truncation is never silent).
    pub fn dropped(&self) -> u64 {
        self.rings.iter().map(|r| r.dropped).sum()
    }

    /// Number of rank lanes ever recorded.
    pub fn lanes(&self) -> usize {
        self.rings.len()
    }

    /// Coordinator-side merge in deterministic order: primary key is the
    /// cumulative SimClock start, ties broken by (rank, per-rank emission
    /// sequence).  Every key is a pure function of the simulation, so the
    /// merged order — like the spans themselves — is identical at any
    /// `--threads` and on either transport.
    pub fn merged(&self) -> Vec<&Span> {
        let mut all: Vec<(&Span, u32, u64)> = Vec::new();
        for ring in &self.rings {
            for (seq, span) in &ring.buf {
                all.push((span, span.rank, *seq));
            }
        }
        all.sort_by(|a, b| {
            a.0.t0
                .total_cmp(&b.0.t0)
                .then(a.1.cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        all.into_iter().map(|(s, _, _)| s).collect()
    }
}

/// Probe that `dir` can be created and written — the early check behind
/// the typed `--trace-out` warning (satellite: unwritable paths warn at
/// startup and at export, never panic mid-epoch).
pub fn validate_out(dir: &std::path::Path) -> Result<(), TraceError> {
    std::fs::create_dir_all(dir).map_err(|e| TraceError::Unwritable {
        path: dir.to_path_buf(),
        reason: e.to_string(),
    })?;
    let probe = dir.join(".trace-probe");
    std::fs::write(&probe, b"probe").map_err(|e| TraceError::Unwritable {
        path: dir.to_path_buf(),
        reason: e.to_string(),
    })?;
    let _ = std::fs::remove_file(&probe);
    Ok(())
}

/// Default per-rank ring capacity (`--trace-ring`): generous for any
/// sweep-sized run (a vit-tiny iteration is ~60 spans/rank).
pub const DEFAULT_RING_CAP: usize = 65_536;

#[cfg(test)]
mod tests {
    use super::*;

    fn span(rank: u32, t0: f64, label: &str) -> Span {
        Span {
            rank,
            epoch: 0,
            giter: 0,
            kind: Kind::Compute,
            label: label.to_string(),
            layer: -1,
            t0,
            dur: 0.1,
            bytes: 0,
            chi: 1.0,
            wall_us: 0,
        }
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut r = RankRing::new(2);
        r.push(span(0, 0.0, "a"));
        r.push(span(0, 1.0, "b"));
        r.push(span(0, 2.0, "c"));
        assert_eq!(r.dropped, 1);
        let labels: Vec<&str> = r.buf.iter().map(|(_, s)| s.label.as_str()).collect();
        assert_eq!(labels, vec!["b", "c"]);
    }

    #[test]
    fn merge_is_time_then_rank_then_seq() {
        let mut tr = Tracer::new(2, 16, true, false);
        tr.compute(1, Kind::Compute, "late", -1, 2.0, 1.0, 1.0); // t0=1.0
        tr.compute(0, Kind::Compute, "early", -1, 0.5, 0.5, 1.0); // t0=0.0
        tr.compute(0, Kind::Compute, "tie_r0", -1, 2.0, 1.0, 1.0); // t0=1.0
        let order: Vec<&str> = tr.merged().iter().map(|s| s.label.as_str()).collect();
        assert_eq!(order, vec!["early", "tie_r0", "late"]);
    }

    #[test]
    fn inactive_tracer_records_nothing() {
        let mut tr = Tracer::new(1, 16, true, true);
        tr.set_active(false);
        tr.begin_iter(0, 0, 0, 0.0, &[1.0]);
        tr.compute(0, Kind::Compute, "x", -1, 1.0, 1.0, 1.0);
        assert!(tr.end_iter(1.0, false).is_none());
        assert!(tr.merged().is_empty());
    }

    #[test]
    fn timeline_sample_accumulates_in_charge_order() {
        let mut tr = Tracer::new(2, 16, false, true);
        tr.begin_iter(7, 1, 3, 10.0, &[1.0, 4.0]);
        tr.compute(0, Kind::Compute, "a", 0, 10.1, 0.1, 1.0);
        tr.compute(1, Kind::Compute, "a", 0, 10.4, 0.4, 4.0);
        tr.compute(1, Kind::Recompute, "recompute", -1, 10.6, 0.2, 1.0);
        let s = tr.end_iter(10.8, true).expect("timeline sample");
        assert_eq!(s.giter, 7);
        assert_eq!((s.epoch, s.iter), (1, 3));
        assert_eq!(s.chi, vec![1.0, 4.0]);
        assert!((s.t_iter[0] - 0.1).abs() < 1e-12);
        assert!((s.t_iter[1] - 0.6).abs() < 1e-12);
        assert!((s.rt_iter_s - 0.8).abs() < 1e-12);
        assert!(s.replanned);
        // spans_on is false: a timeline-only tracer buffers no spans
        assert!(tr.merged().is_empty());
    }

    #[test]
    fn epoch_rollover_offsets_t0() {
        let mut tr = Tracer::new(1, 16, true, false);
        tr.compute(0, Kind::Compute, "e0", -1, 1.0, 1.0, 1.0);
        tr.epoch_rollover(5.0);
        tr.compute(0, Kind::Compute, "e1", -1, 1.0, 1.0, 1.0); // raw t0=0 again
        let m = tr.merged();
        assert_eq!(m[0].t0, 0.0);
        assert_eq!(m[1].t0, 5.0);
    }

    #[test]
    fn sim_eq_ignores_wall_only() {
        let a = span(0, 1.0, "x");
        let mut b = a.clone();
        b.wall_us = 999;
        assert!(a.sim_eq(&b));
        b.dur += 1e-9;
        assert!(!a.sim_eq(&b));
    }

    #[test]
    fn unwritable_out_is_typed() {
        let dir = std::env::temp_dir().join(format!("flextp_trace_probe_{}", std::process::id()));
        std::fs::write(&dir, b"a file, not a dir").unwrap();
        let err = validate_out(&dir.join("sub")).expect_err("must be unwritable");
        assert!(matches!(err, TraceError::Unwritable { .. }));
        assert!(err.to_string().contains("Unwritable"));
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn chi_excess_matches_injected_slowdown() {
        // χ=6 on 0.6s of skewed time: base work 0.1s, excess 0.5s
        let mut s = span(0, 0.0, "x");
        s.dur = 0.6;
        s.chi = 6.0;
        assert!((s.chi_excess_s() - 0.5).abs() < 1e-12);
        s.chi = 1.0;
        assert_eq!(s.chi_excess_s(), 0.0);
    }
}
