//! Collective communication over the simulated worker group.
//!
//! Data movement is real (buffers are summed/copied between rank slots);
//! time is charged through the α-β cost model in [`cost`].  Algorithms
//! match what the paper compares: ring all-reduce/all-gather (NCCL-style,
//! what Colossal-AI's 1D TP uses), **tree** broadcast/reduce (the paper's
//! chosen migration primitives), and **flat** scatter/gather (the
//! conventional baseline of Table I).
//!
//! With the parallel rank engine, the *data* reduction of
//! [`Comm::all_reduce`] runs as a fixed binary tree whose summation order
//! depends only on the group size — never on which rank's worker thread
//! finished first — so results are reproducible at any `--threads`.

pub mod cost;
pub mod transport;

use std::sync::{Arc, Mutex};

use crate::cluster::Clocks;
use crate::tensor::Tensor;
use crate::trace::{Kind, Tracer};
use cost::CostModel;
use transport::{InProc, Transport, TransportError};

/// Byte/op accounting per collective family (metrics + Φ₁ fitting).
#[derive(Debug, Clone, Default)]
pub struct CommStats {
    pub allreduce_ops: u64,
    pub allreduce_bytes: u64,
    pub broadcast_ops: u64,
    pub broadcast_bytes: u64,
    pub reduce_ops: u64,
    pub reduce_bytes: u64,
    pub scatter_ops: u64,
    pub scatter_bytes: u64,
    pub gather_ops: u64,
    pub gather_bytes: u64,
    pub allgather_ops: u64,
    pub allgather_bytes: u64,
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.allreduce_bytes + self.broadcast_bytes + self.reduce_bytes
            + self.scatter_bytes + self.gather_bytes + self.allgather_bytes
    }
}

/// The collective engine: cost model + stats + a pluggable data plane.
///
/// Accounting (simulated clocks, α-β costs, `CommStats`) always runs here
/// on the coordinator; only the all-reduce *data movement* is delegated
/// to the [`Transport`] — which is why every transport produces identical
/// simulated metrics by construction (DESIGN.md §15).
#[derive(Debug)]
pub struct Comm {
    pub cost: CostModel,
    pub stats: CommStats,
    /// The all-reduce data plane: [`InProc`] (buffer slots in this
    /// process, the historic engine) or
    /// [`LocalTcp`](transport::LocalTcp) (OS-process ranks).
    pub transport: Box<dyn Transport>,
    /// Shared span recorder (DESIGN.md §17).  Records the wait-vs-transfer
    /// split of every collective by *reading* the clocks before the
    /// barrier — it never advances them, so a traced run's clocks, stats,
    /// and data stay bitwise identical to an untraced one, on either
    /// transport.
    pub tracer: Option<Arc<Mutex<Tracer>>>,
}

impl Comm {
    pub fn new(cost: CostModel) -> Comm {
        Comm::with_transport(cost, Box::new(InProc))
    }

    pub fn with_transport(cost: CostModel, transport: Box<dyn Transport>) -> Comm {
        Comm { cost, stats: CommStats::default(), transport, tracer: None }
    }

    /// Record each member's pre-barrier wait (the straggler tax) and
    /// return the members' clock frontier — the point where the
    /// collective's transfer phase starts.  Reads clocks only.
    fn trace_pre(&self, clocks: &Clocks, members: &[usize], label: &str) -> f64 {
        let mut mx = f64::NEG_INFINITY;
        for &r in members {
            mx = mx.max(clocks.now(r));
        }
        if let Some(tr) = &self.tracer {
            let mut tr = tr.lock().expect("tracer lock");
            if tr.comm_enabled() {
                for &r in members {
                    let w = mx - clocks.now(r);
                    if w > 0.0 {
                        tr.comm_wait(r, label, clocks.now(r), w);
                    }
                }
            }
        }
        mx
    }

    /// Record the transfer phase on each member: `t0` is the frontier
    /// returned by [`Comm::trace_pre`], `dur` the cost-model charge just
    /// applied to the clocks, `bytes` the member's payload share.
    fn trace_xfer(&self, members: &[usize], kind: Kind, label: &str, t0: f64, dur: f64, bytes: u64) {
        if let Some(tr) = &self.tracer {
            let mut tr = tr.lock().expect("tracer lock");
            if tr.comm_enabled() {
                for &r in members {
                    tr.comm_xfer(r, kind, label, t0, dur, bytes);
                }
            }
        }
    }

    fn tracing(&self) -> bool {
        match &self.tracer {
            Some(tr) => tr.lock().expect("tracer lock").comm_enabled(),
            None => false,
        }
    }

    /// All-reduce: every rank ends with the elementwise sum.
    /// Synchronizes all ranks (barrier) then charges ring time.
    /// This is the paper's per-branch collection collective.
    ///
    /// The data reduction is a **fixed binary tree**: at stride d the rank
    /// pairs (i, i+d) combine, so the f32 summation order is a function of
    /// e alone — never of rank arrival order or thread interleaving — and
    /// a `--threads 1` run and a `--threads N` run produce bitwise-equal
    /// sums (the parity invariant of `tests/parallel_determinism.rs`).
    /// The same order is what [`transport::LocalTcp`] distributes over
    /// rank processes, so transports are bitwise-interchangeable too
    /// (`tests/transport_parity.rs`).  Time is still charged with the
    /// ring α-β model the paper assumes.  `phase` labels the collective
    /// in transport errors.
    pub fn all_reduce(
        &mut self,
        clocks: &mut Clocks,
        phase: &str,
        bufs: &mut [Tensor],
    ) -> Result<(), TransportError> {
        let e = bufs.len();
        debug_assert_eq!(e, clocks.e());
        let bytes = bufs[0].size_bytes();
        let pre = if self.tracing() {
            let members: Vec<usize> = (0..e).collect();
            Some((self.trace_pre(clocks, &members, phase), members))
        } else {
            None
        };
        self.transport.all_reduce(phase, bufs)?;
        clocks.barrier();
        let dt = self.cost.ring_allreduce(e, bytes);
        for r in 0..e {
            clocks.advance_comm(r, dt);
        }
        self.stats.allreduce_ops += 1;
        self.stats.allreduce_bytes += bytes as u64;
        if let Some((t0, members)) = pre {
            self.trace_xfer(&members, Kind::CommXfer, phase, t0, dt, bytes as u64);
        }
        Ok(())
    }

    /// Several independent all-reduces at once.  The transport may
    /// overlap the groups' collective waits (the Megatron column/row
    /// overlap discipline — `LocalTcp` submits every group's frames
    /// before collecting any sum); the accounting below replays the
    /// exact barrier/cost sequence of sequential [`Comm::all_reduce`]
    /// calls, so clocks, stats, and data are bitwise identical to the
    /// unbatched form on every transport.
    pub fn all_reduce_batch(
        &mut self,
        clocks: &mut Clocks,
        phase: &str,
        groups: &mut [&mut [Tensor]],
    ) -> Result<(), TransportError> {
        if groups.is_empty() {
            return Ok(());
        }
        let e = groups[0].len();
        debug_assert_eq!(e, clocks.e());
        let sizes: Vec<usize> = groups.iter().map(|g| g[0].size_bytes()).collect();
        // only the first group's barrier can observe skew (the replay
        // below equalizes all clocks); record waits once, then walk a
        // transfer cursor group by group
        let mut pre = if self.tracing() {
            let members: Vec<usize> = (0..e).collect();
            Some((self.trace_pre(clocks, &members, phase), members))
        } else {
            None
        };
        self.transport.all_reduce_batch(phase, groups)?;
        for bytes in sizes {
            clocks.barrier();
            let dt = self.cost.ring_allreduce(e, bytes);
            for r in 0..e {
                clocks.advance_comm(r, dt);
            }
            self.stats.allreduce_ops += 1;
            self.stats.allreduce_bytes += bytes as u64;
            if let Some((t_cursor, members)) = &mut pre {
                self.trace_xfer(members, Kind::CommXfer, phase, *t_cursor, dt, bytes as u64);
                *t_cursor += dt;
            }
        }
        Ok(())
    }

    /// All-reduce over a **component sub-group** (DESIGN.md §18): the
    /// rank prefix `0..bufs.len()` of an `e_total`-rank process group.
    /// Only members synchronize (`barrier_of`), only members are
    /// charged, and the ring cost is priced at the *sub-group* size —
    /// non-member clocks never move.  When the sub-group is the whole
    /// group this delegates to [`Comm::all_reduce`], so uniform-degree
    /// runs keep the historic accounting and trace labels bit for bit.
    /// Sub-group collectives are labelled `{phase}@g{n}` in traces and
    /// transport errors.
    pub fn all_reduce_group(
        &mut self,
        clocks: &mut Clocks,
        phase: &str,
        bufs: &mut [Tensor],
        e_total: usize,
    ) -> Result<(), TransportError> {
        let g = bufs.len();
        if g == e_total {
            return self.all_reduce(clocks, phase, bufs);
        }
        debug_assert!(g >= 1 && g < e_total);
        debug_assert_eq!(e_total, clocks.e());
        let label = format!("{phase}@g{g}");
        let bytes = bufs[0].size_bytes();
        let members: Vec<usize> = (0..g).collect();
        let pre = if self.tracing() {
            Some(self.trace_pre(clocks, &members, &label))
        } else {
            None
        };
        self.transport.all_reduce_prefix_batch(&label, &mut [bufs], e_total)?;
        clocks.barrier_of(&members);
        let dt = self.cost.ring_allreduce(g, bytes);
        for &r in &members {
            clocks.advance_comm(r, dt);
        }
        self.stats.allreduce_ops += 1;
        self.stats.allreduce_bytes += bytes as u64;
        if let Some(t0) = pre {
            self.trace_xfer(&members, Kind::CommXfer, &label, t0, dt, bytes as u64);
        }
        Ok(())
    }

    /// Several independent sub-group all-reduces at once, all over the
    /// same `e_total`-rank process group but with per-group member
    /// counts.  Data moves in one overlapped transport submission; the
    /// accounting replays sequential [`Comm::all_reduce_group`] calls
    /// group by group (member-only barriers and charges), so clocks,
    /// stats, and traces are bitwise identical to the unbatched form.
    pub fn all_reduce_group_batch(
        &mut self,
        clocks: &mut Clocks,
        phase: &str,
        groups: &mut [&mut [Tensor]],
        e_total: usize,
    ) -> Result<(), TransportError> {
        if groups.is_empty() {
            return Ok(());
        }
        if groups.iter().all(|g| g.len() == e_total) {
            return self.all_reduce_batch(clocks, phase, groups);
        }
        let metas: Vec<(usize, usize)> =
            groups.iter().map(|g| (g.len(), g[0].size_bytes())).collect();
        self.transport.all_reduce_prefix_batch(phase, groups, e_total)?;
        for (g, bytes) in metas {
            // full-size groups inside a mixed batch keep the plain phase
            // label, exactly like the unbatched delegate path
            let label =
                if g == e_total { phase.to_string() } else { format!("{phase}@g{g}") };
            let members: Vec<usize> = (0..g).collect();
            let pre = if self.tracing() {
                Some(self.trace_pre(clocks, &members, &label))
            } else {
                None
            };
            clocks.barrier_of(&members);
            let dt = self.cost.ring_allreduce(g, bytes);
            for &r in &members {
                clocks.advance_comm(r, dt);
            }
            self.stats.allreduce_ops += 1;
            self.stats.allreduce_bytes += bytes as u64;
            if let Some(t0) = pre {
                self.trace_xfer(&members, Kind::CommXfer, &label, t0, dt, bytes as u64);
            }
        }
        Ok(())
    }

    /// All-gather of per-rank scalars (e.g. the T_i runtime list of
    /// Algorithm 2 line 2). Returns the gathered vector.
    pub fn all_gather_scalars(&mut self, clocks: &mut Clocks, vals: &[f64]) -> Vec<f64> {
        let e = vals.len();
        let pre = if self.tracing() {
            let members: Vec<usize> = (0..e).collect();
            Some((self.trace_pre(clocks, &members, "detect"), members))
        } else {
            None
        };
        clocks.barrier();
        let bytes = 8 * e;
        let dt = self.cost.ring_allgather(e, bytes);
        for r in 0..e {
            clocks.advance_comm(r, dt);
        }
        self.stats.allgather_ops += 1;
        self.stats.allgather_bytes += bytes as u64;
        if let Some((t0, members)) = pre {
            self.trace_xfer(&members, Kind::Detect, "detect", t0, dt, bytes as u64);
        }
        vals.to_vec()
    }

    /// Tree broadcast from `root` to `peers`: charges log2-depth rounds.
    /// Root and receivers advance together (receivers that joined the tree
    /// early relay onward — the paper's "new senders" scalability note).
    pub fn broadcast(&mut self, clocks: &mut Clocks, root: usize, peers: &[usize], bytes: usize) {
        if peers.is_empty() {
            return;
        }
        let mut all = vec![root];
        all.extend_from_slice(peers);
        let t0 = self.trace_pre(clocks, &all, "mig_bcast");
        clocks.barrier_of(&all);
        let dt = self.cost.tree_rounds(peers.len() + 1, bytes);
        for &r in &all {
            clocks.advance_comm(r, dt);
        }
        self.stats.broadcast_ops += 1;
        self.stats.broadcast_bytes += (bytes * peers.len()) as u64;
        self.trace_xfer(&all, Kind::Migration, "mig_bcast", t0, dt, bytes as u64);
    }

    /// Flat scatter: root sends a distinct `bytes`-sized slice to each
    /// peer sequentially (the single-sender bottleneck of Table I).
    pub fn scatter(&mut self, clocks: &mut Clocks, root: usize, peers: &[usize], bytes_each: usize) {
        if peers.is_empty() {
            return;
        }
        let mut all = vec![root];
        all.extend_from_slice(peers);
        let pre = self.trace_pre(clocks, &all, "mig_scatter");
        clocks.barrier_of(&all);
        let per = self.cost.p2p(bytes_each);
        // peer i can proceed after (i+1) sequential sends; root after all.
        let t0 = clocks.now(root);
        for (i, &p) in peers.iter().enumerate() {
            let tp = t0 + per * (i + 1) as f64;
            let dt = (tp - clocks.now(p)).max(0.0);
            clocks.advance_comm(p, dt);
            self.trace_xfer(&[p], Kind::Migration, "mig_scatter", pre, dt, bytes_each as u64);
        }
        let dtr = per * peers.len() as f64;
        clocks.advance_comm(root, dtr);
        self.stats.scatter_ops += 1;
        self.stats.scatter_bytes += (bytes_each * peers.len()) as u64;
        self.trace_xfer(&[root], Kind::Migration, "mig_scatter", pre, dtr,
                        (bytes_each * peers.len()) as u64);
    }

    /// Tree reduce of per-peer partials to `root`. The data reduction
    /// (summing `bufs` into the root slot) is the caller's job when
    /// buffers overlap; this charges time/stats.
    pub fn reduce(&mut self, clocks: &mut Clocks, root: usize, peers: &[usize], bytes: usize) {
        if peers.is_empty() {
            return;
        }
        let mut all = vec![root];
        all.extend_from_slice(peers);
        let t0 = self.trace_pre(clocks, &all, "mig_reduce");
        clocks.barrier_of(&all);
        let dt = self.cost.tree_rounds(peers.len() + 1, bytes);
        for &r in &all {
            clocks.advance_comm(r, dt);
        }
        self.stats.reduce_ops += 1;
        self.stats.reduce_bytes += (bytes * peers.len()) as u64;
        self.trace_xfer(&all, Kind::Migration, "mig_reduce", t0, dt, bytes as u64);
    }

    /// Flat gather: each peer sends `bytes_each` to root sequentially.
    pub fn gather(&mut self, clocks: &mut Clocks, root: usize, peers: &[usize], bytes_each: usize) {
        if peers.is_empty() {
            return;
        }
        let mut all = vec![root];
        all.extend_from_slice(peers);
        let t0 = self.trace_pre(clocks, &all, "mig_gather");
        clocks.barrier_of(&all);
        let per = self.cost.p2p(bytes_each);
        let dtr = per * peers.len() as f64;
        clocks.advance_comm(root, dtr);
        for &p in peers {
            clocks.advance_comm(p, per);
            self.trace_xfer(&[p], Kind::Migration, "mig_gather", t0, per, bytes_each as u64);
        }
        self.stats.gather_ops += 1;
        self.stats.gather_bytes += (bytes_each * peers.len()) as u64;
        self.trace_xfer(&[root], Kind::Migration, "mig_gather", t0, dtr,
                        (bytes_each * peers.len()) as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_comm() -> Comm {
        Comm::new(CostModel { alpha_s: 1e-5, bytes_per_s: 1e9 })
    }

    #[test]
    fn allreduce_sums_everywhere() {
        let mut comm = mk_comm();
        let mut clocks = Clocks::new(3);
        let mut bufs = vec![
            Tensor::from_vec(&[2], vec![1.0, 2.0]),
            Tensor::from_vec(&[2], vec![10.0, 20.0]),
            Tensor::from_vec(&[2], vec![100.0, 200.0]),
        ];
        comm.all_reduce(&mut clocks, "test", &mut bufs).unwrap();
        for b in &bufs {
            assert_eq!(b.data, vec![111.0, 222.0]);
        }
        assert!(clocks.now(0) > 0.0);
        assert_eq!(comm.stats.allreduce_ops, 1);
    }

    #[test]
    fn allreduce_barriers_to_slowest() {
        let mut comm = mk_comm();
        let mut clocks = Clocks::new(2);
        clocks.advance(1, 5.0); // straggler
        let mut bufs = vec![Tensor::zeros(&[4]), Tensor::zeros(&[4])];
        comm.all_reduce(&mut clocks, "test", &mut bufs).unwrap();
        // rank 0 waited for rank 1 — the waiting cost
        assert!(clocks.now(0) >= 5.0);
        assert_eq!(clocks.now(0), clocks.now(1));
    }

    #[test]
    fn broadcast_cheaper_than_scatter_for_many_peers() {
        // The Table I asymmetry: tree broadcast O(log n) rounds vs flat
        // scatter O(n) sends from the straggler.
        let bytes = 1_000_000;
        let peers: Vec<usize> = (1..8).collect();

        let mut c1 = mk_comm();
        let mut k1 = Clocks::new(8);
        c1.broadcast(&mut k1, 0, &peers, bytes);
        let t_bcast = k1.now(0);

        let mut c2 = mk_comm();
        let mut k2 = Clocks::new(8);
        c2.scatter(&mut k2, 0, &peers, bytes);
        let t_scatter = k2.now(0);

        assert!(t_bcast < t_scatter, "bcast={t_bcast} scatter={t_scatter}");
    }

    #[test]
    fn scatter_peers_staggered() {
        let mut c = mk_comm();
        let mut k = Clocks::new(4);
        c.scatter(&mut k, 0, &[1, 2, 3], 1000);
        assert!(k.now(1) < k.now(2));
        assert!(k.now(2) < k.now(3));
        assert!((k.now(3) - k.now(0)).abs() < 1e-12); // last peer = root done
    }

    #[test]
    fn gather_root_pays_linear() {
        let mut c = mk_comm();
        let mut k = Clocks::new(4);
        c.gather(&mut k, 0, &[1, 2, 3], 1000);
        let per = c.cost.p2p(1000);
        assert!((k.now(0) - 3.0 * per).abs() < 1e-12);
        assert!((k.now(1) - per).abs() < 1e-12);
    }

    #[test]
    fn allreduce_tree_order_is_fixed_and_repeatable() {
        // The tree reduction depends only on e: the same inputs reduce to
        // bitwise-identical sums on every call, regardless of how skewed
        // the rank clocks are when the collective fires (the "arrival
        // order" of the simulated ranks).
        let mk = |skew: &[f64]| {
            let mut comm = mk_comm();
            let mut clocks = Clocks::new(5);
            for (r, &s) in skew.iter().enumerate() {
                clocks.advance(r, s);
            }
            let mut bufs: Vec<Tensor> = (0..5)
                .map(|r| {
                    Tensor::from_vec(&[3], vec![0.1 * r as f32, 1.0 / (r + 1) as f32, 1e-3])
                })
                .collect();
            comm.all_reduce(&mut clocks, "test", &mut bufs).unwrap();
            bufs[0].data.clone()
        };
        let a = mk(&[0.0, 0.0, 0.0, 0.0, 0.0]);
        let b = mk(&[9.0, 1.0, 5.0, 0.0, 2.0]);
        assert_eq!(a, b, "reduction must not depend on rank clock skew");
        // and the sum is still the exact elementwise sum (f64 reference)
        let want: f64 = (0..5).map(|r| 0.1 * r as f64).sum();
        assert!((a[0] as f64 - want).abs() < 1e-6);
    }

    #[test]
    fn stats_accumulate() {
        let mut c = mk_comm();
        let mut k = Clocks::new(2);
        let mut bufs = vec![Tensor::zeros(&[8]), Tensor::zeros(&[8])];
        c.all_reduce(&mut k, "test", &mut bufs).unwrap();
        c.all_reduce(&mut k, "test", &mut bufs).unwrap();
        c.broadcast(&mut k, 0, &[1], 100);
        assert_eq!(c.stats.allreduce_ops, 2);
        assert_eq!(c.stats.allreduce_bytes, 64);
        assert_eq!(c.stats.total_bytes(), 64 + 100);
    }

    #[test]
    fn tracing_is_zero_observer_on_collectives() {
        // attaching a tracer must not move a single clock bit or stat;
        // it only *adds* the recorded wait/xfer split
        let run = |traced: bool| {
            let mut c = mk_comm();
            if traced {
                c.tracer = Some(Arc::new(Mutex::new(Tracer::new(3, 1024, true, false))));
            }
            let mut k = Clocks::new(3);
            k.advance(1, 2.0); // skew so waits are non-trivial
            let mut bufs: Vec<Tensor> = (0..3).map(|_| Tensor::zeros(&[8])).collect();
            c.all_reduce(&mut k, "p", &mut bufs).unwrap();
            let mut g1: Vec<Tensor> = (0..3).map(|_| Tensor::zeros(&[4])).collect();
            let mut g2: Vec<Tensor> = (0..3).map(|_| Tensor::zeros(&[2])).collect();
            c.all_reduce_batch(&mut k, "p", &mut [&mut g1[..], &mut g2[..]]).unwrap();
            c.broadcast(&mut k, 0, &[1, 2], 100);
            c.scatter(&mut k, 0, &[1, 2], 50);
            c.reduce(&mut k, 1, &[0, 2], 60);
            c.gather(&mut k, 2, &[0, 1], 70);
            let _ = c.all_gather_scalars(&mut k, &[1.0, 2.0, 3.0]);
            let bits: Vec<u64> = (0..3).map(|r| k.now(r).to_bits()).collect();
            (bits, c.stats.total_bytes(), c.stats.allreduce_ops, c)
        };
        let (ka, ba, oa, ca) = run(false);
        let (kb, bb, ob, cb) = run(true);
        assert_eq!(ka, kb, "clocks must be bitwise identical traced vs untraced");
        assert_eq!((ba, oa), (bb, ob));
        assert!(ca.tracer.is_none());
        let tr = cb.tracer.expect("tracer attached");
        let tr = tr.lock().unwrap();
        let m = tr.merged();
        assert!(m.iter().any(|s| s.kind == Kind::CommWait && s.dur > 0.0));
        assert!(m.iter().any(|s| s.kind == Kind::CommXfer && s.bytes > 0));
        assert!(m.iter().any(|s| s.kind == Kind::Migration && s.label == "mig_scatter"));
        assert!(m.iter().any(|s| s.kind == Kind::Detect));
    }

    #[test]
    fn group_allreduce_full_size_delegates_to_legacy_path() {
        // g == e_total must be indistinguishable from plain all_reduce
        let mk = |grouped: bool| {
            let mut c = mk_comm();
            let mut k = Clocks::new(3);
            k.advance(2, 1.5);
            let mut bufs: Vec<Tensor> =
                (0..3).map(|r| Tensor::from_vec(&[2], vec![r as f32, 1.0])).collect();
            if grouped {
                c.all_reduce_group(&mut k, "p", &mut bufs, 3).unwrap();
            } else {
                c.all_reduce(&mut k, "p", &mut bufs).unwrap();
            }
            let clocks: Vec<u64> = (0..3).map(|r| k.now(r).to_bits()).collect();
            (bufs[0].data.clone(), clocks, c.stats.allreduce_bytes)
        };
        assert_eq!(mk(true), mk(false));
    }

    #[test]
    fn group_allreduce_charges_members_only() {
        let mut c = mk_comm();
        let mut k = Clocks::new(4);
        k.advance(0, 1.0);
        k.advance(3, 9.0); // non-member straggler must NOT drag the group
        let mut bufs = vec![
            Tensor::from_vec(&[2], vec![1.0, 2.0]),
            Tensor::from_vec(&[2], vec![10.0, 20.0]),
        ];
        c.all_reduce_group(&mut k, "p", &mut bufs, 4).unwrap();
        for b in &bufs {
            assert_eq!(b.data, vec![11.0, 22.0]);
        }
        // members barrier to the member frontier (1.0) + g-sized ring cost
        let dt = c.cost.ring_allreduce(2, 8);
        assert_eq!(k.now(0), 1.0 + dt);
        assert_eq!(k.now(1), 1.0 + dt);
        // non-members untouched — bitwise
        assert_eq!(k.now(2), 0.0);
        assert_eq!(k.now(3), 9.0);
        assert_eq!(c.stats.allreduce_ops, 1);
        assert_eq!(c.stats.allreduce_bytes, 8);
    }

    #[test]
    fn group_batch_matches_sequential_group_calls() {
        let mk_bufs = || {
            (
                vec![
                    Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]),
                    Tensor::from_vec(&[3], vec![4.0, 5.0, 6.0]),
                ],
                vec![
                    Tensor::from_vec(&[2], vec![0.1, 0.2]),
                    Tensor::from_vec(&[2], vec![0.3, 0.4]),
                    Tensor::from_vec(&[2], vec![0.5, 0.6]),
                    Tensor::from_vec(&[2], vec![0.7, 0.8]),
                ],
            )
        };
        let (mut s1, mut s2) = mk_bufs();
        let mut cs = mk_comm();
        let mut ks = Clocks::new(4);
        ks.advance(1, 2.0);
        cs.all_reduce_group(&mut ks, "p", &mut s1, 4).unwrap();
        cs.all_reduce_group(&mut ks, "p", &mut s2, 4).unwrap();

        let (mut b1, mut b2) = mk_bufs();
        let mut cb = mk_comm();
        let mut kb = Clocks::new(4);
        kb.advance(1, 2.0);
        cb.all_reduce_group_batch(&mut kb, "p", &mut [&mut b1[..], &mut b2[..]], 4)
            .unwrap();

        for (s, b) in s1.iter().zip(&b1).chain(s2.iter().zip(&b2)) {
            assert_eq!(s.data, b.data);
        }
        for r in 0..4 {
            assert_eq!(ks.now(r).to_bits(), kb.now(r).to_bits(), "rank {r} clock");
        }
        assert_eq!(cs.stats.allreduce_ops, cb.stats.allreduce_ops);
        assert_eq!(cs.stats.allreduce_bytes, cb.stats.allreduce_bytes);
    }

    #[test]
    fn tracing_is_zero_observer_on_group_collectives() {
        let run = |traced: bool| {
            let mut c = mk_comm();
            if traced {
                c.tracer = Some(Arc::new(Mutex::new(Tracer::new(4, 1024, true, false))));
            }
            let mut k = Clocks::new(4);
            k.advance(1, 2.0);
            let mut g1: Vec<Tensor> = (0..2).map(|_| Tensor::zeros(&[4])).collect();
            c.all_reduce_group(&mut k, "p", &mut g1, 4).unwrap();
            let mut g2: Vec<Tensor> = (0..2).map(|_| Tensor::zeros(&[4])).collect();
            let mut g3: Vec<Tensor> = (0..4).map(|_| Tensor::zeros(&[2])).collect();
            c.all_reduce_group_batch(&mut k, "p", &mut [&mut g2[..], &mut g3[..]], 4)
                .unwrap();
            let bits: Vec<u64> = (0..4).map(|r| k.now(r).to_bits()).collect();
            (bits, c.stats.total_bytes(), c)
        };
        let (ka, ba, _) = run(false);
        let (kb, bb, cb) = run(true);
        assert_eq!(ka, kb, "clocks must be bitwise identical traced vs untraced");
        assert_eq!(ba, bb);
        let tr = cb.tracer.expect("tracer attached");
        let tr = tr.lock().unwrap();
        let m = tr.merged();
        assert!(
            m.iter().any(|s| s.kind == Kind::CommXfer && s.label == "p@g2"),
            "sub-group transfers must carry the @g label"
        );
    }

    #[test]
    fn batch_matches_sequential_accounting_and_data() {
        // the overlapped batch form must be indistinguishable from
        // sequential calls: same sums, same clocks, same stats
        let mk_bufs = || {
            vec![
                vec![
                    Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]),
                    Tensor::from_vec(&[3], vec![4.0, 5.0, 6.0]),
                ],
                vec![
                    Tensor::from_vec(&[5], vec![0.1; 5]),
                    Tensor::from_vec(&[5], vec![0.2; 5]),
                ],
            ]
        };
        let mut seq = mk_bufs();
        let mut cs = mk_comm();
        let mut ks = Clocks::new(2);
        ks.advance(1, 3.0); // skewed start must not matter
        for g in seq.iter_mut() {
            cs.all_reduce(&mut ks, "test", g).unwrap();
        }

        let mut bat = mk_bufs();
        let mut cb = mk_comm();
        let mut kb = Clocks::new(2);
        kb.advance(1, 3.0);
        let (a, b) = bat.split_at_mut(1);
        cb.all_reduce_batch(&mut kb, "test", &mut [&mut a[0][..], &mut b[0][..]]).unwrap();

        for (gs, gb) in seq.iter().zip(bat.iter()) {
            for (ts, tb) in gs.iter().zip(gb.iter()) {
                assert_eq!(ts.data, tb.data);
            }
        }
        assert_eq!(ks.now(0), kb.now(0));
        assert_eq!(ks.now(1), kb.now(1));
        assert_eq!(cs.stats.allreduce_ops, cb.stats.allreduce_ops);
        assert_eq!(cs.stats.allreduce_bytes, cb.stats.allreduce_bytes);
    }
}
