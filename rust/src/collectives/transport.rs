//! Pluggable collective transport (DESIGN.md §15).
//!
//! [`Comm`](super::Comm) owns the *accounting* of every collective (the
//! α-β cost model, the simulated clocks, `CommStats`); the only collective
//! that moves real data is the all-reduce.  This module makes that data
//! plane pluggable behind the [`Transport`] trait:
//!
//! * [`InProc`] — the historic engine: every rank is a buffer slot in the
//!   coordinator's address space and the reduction is the fixed
//!   binary-tree stride loop, byte for byte what the code has always done.
//! * [`LocalTcp`] — every rank is an **OS process** (`flextp rank …`,
//!   re-exec of the current binary) connected over localhost TCP with
//!   length-prefixed, checksummed frames.  The reduction runs over the
//!   *same* fixed binary tree, expressed as its binomial-tree form
//!   (rank `j` receives the partials of children `j+d` for every stride
//!   `d` with `j ≡ 0 (mod 2d)`, in increasing-stride order, then forwards
//!   to parent `j − lowbit(j)`), so f32 sums are **bitwise identical** to
//!   `InProc` — determinism survives the wire
//!   (`tests/transport_parity.rs`).
//!
//! Every failure maps to a typed [`TransportError`] — never a panic, and
//! never an unbounded hang: all reads carry bounded timeouts, connects
//! use exponential backoff with a deadline, and a dead peer is identified
//! by probing the child processes (`try_wait`) so a SIGKILL surfaces as
//! [`TransportError::PeerDied`] rather than a bare socket error.  The
//! trainer routes `PeerDied` into the PR 6 churn path: snapshot-restore
//! onto the nearest-divisor worker count, exactly the
//! kill/checkpoint/`--resume --e E'` oracle (`tests/transport_faults.rs`).

use std::fmt;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::tensor::Tensor;

// ---------------------------------------------------------------------
// Wire constants (documented in DESIGN.md §15)
// ---------------------------------------------------------------------

/// Frame preamble: any stream not starting with this is a `BadFrame`.
pub const MAGIC: [u8; 4] = *b"FLXT";
/// Hard payload ceiling (16 MiB) — a corrupt length field fails fast as
/// `BadFrame` instead of attempting a multi-gigabyte allocation.
pub const MAX_FRAME: usize = 1 << 24;
/// First retry delay of the exponential-backoff connect loop.
pub const CONNECT_BACKOFF_START_MS: u64 = 1;
/// Backoff cap: retries never sleep longer than this between attempts.
pub const CONNECT_BACKOFF_CAP_MS: u64 = 200;
/// Total budget for one backoff connect before `ConnRefused`.
pub const CONNECT_DEADLINE_MS: u64 = 10_000;
/// Group handshake budget (spawn → hello → topology → ready).  Decoupled
/// from the per-collective read timeout so a deliberately tiny
/// `--transport-timeout-ms` (fault tests) still lets the group form.
pub const HANDSHAKE_TIMEOUT_MS: u64 = 30_000;
/// Rank-side idle read timeout.  Deliberately much longer than the
/// coordinator-side default so a stalled peer is always diagnosed by the
/// coordinator (typed `Timeout`) before the rank-side cascade fires.
pub const RANK_IDLE_TIMEOUT_MS: u64 = 60_000;
/// Coordinator-side default per-read timeout (`--transport-timeout-ms`).
pub const DEFAULT_COORD_TIMEOUT_MS: u64 = 10_000;

// ---------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------

/// Every way the transport can fail.  The contract: any I/O anomaly,
/// malformed frame, or peer death decodes to exactly one of these —
/// callers never see a panic, a hang, or an untyped error string.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// Could not connect within the backoff deadline.
    ConnRefused { addr: String },
    /// The stream ended inside a frame (peer closed mid-message).
    Truncated { got: usize, want: usize },
    /// Structurally invalid frame: bad magic, oversized length, checksum
    /// mismatch, unknown kind, or a frame out of protocol order.
    BadFrame { reason: String },
    /// A rank process is gone (exited or signal-killed).
    PeerDied { rank: usize },
    /// A bounded read/write deadline expired with all peers still alive.
    Timeout { waiting_for: String },
    /// Any other I/O error, with its kind preserved for matching.
    Io { context: String, kind: io::ErrorKind },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::ConnRefused { addr } => {
                write!(f, "connection to {addr} refused (backoff deadline exhausted)")
            }
            TransportError::Truncated { got, want } => {
                write!(f, "frame truncated: got {got} of {want} bytes")
            }
            TransportError::BadFrame { reason } => write!(f, "bad frame: {reason}"),
            TransportError::PeerDied { rank } => write!(f, "rank {rank} process died"),
            TransportError::Timeout { waiting_for } => {
                write!(f, "transport timeout waiting for {waiting_for}")
            }
            TransportError::Io { context, kind } => write!(f, "transport i/o ({context}): {kind}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// Classify a raw I/O error from a socket read/write.  EOF means the
/// peer closed mid-frame; WouldBlock/TimedOut are the bounded-read
/// deadline (both appear depending on platform).
fn map_io(err: io::Error, context: &str) -> TransportError {
    match err.kind() {
        io::ErrorKind::UnexpectedEof => TransportError::Truncated { got: 0, want: 1 },
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
            TransportError::Timeout { waiting_for: context.to_string() }
        }
        kind => TransportError::Io { context: context.to_string(), kind },
    }
}

// ---------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------

/// Message kinds carried on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// rank → coordinator / parent: identify self; payload = child-facing
    /// listen port (u16 LE, 0 when the rank is a leaf).
    Hello = 1,
    /// coordinator → rank: payload = group size `e` (u16 LE) + the
    /// rank's parent listen port (u16 LE, 0 for rank 0).
    Topology = 2,
    /// coordinator → rank: one all-reduce input; payload = f32 LE data.
    Work = 3,
    /// child → parent: subtree partial sum; payload = f32 LE data.
    Partial = 4,
    /// rank 0 → coordinator: the full tree sum; payload = f32 LE data.
    Sum = 5,
    /// rank → coordinator: handshake complete (tree links are up).
    Ready = 6,
    /// coordinator → rank: exit cleanly.
    Shutdown = 7,
}

impl FrameKind {
    pub fn from_u8(v: u8) -> Option<FrameKind> {
        match v {
            1 => Some(FrameKind::Hello),
            2 => Some(FrameKind::Topology),
            3 => Some(FrameKind::Work),
            4 => Some(FrameKind::Partial),
            5 => Some(FrameKind::Sum),
            6 => Some(FrameKind::Ready),
            7 => Some(FrameKind::Shutdown),
            _ => None,
        }
    }

    /// Every kind, for round-trip property tests.
    pub fn all() -> [FrameKind; 7] {
        [
            FrameKind::Hello,
            FrameKind::Topology,
            FrameKind::Work,
            FrameKind::Partial,
            FrameKind::Sum,
            FrameKind::Ready,
            FrameKind::Shutdown,
        ]
    }
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub rank: u16,
    pub seq: u32,
    pub payload: Vec<u8>,
}

/// FNV-1a 64-bit over the header-after-magic plus payload: cheap, no
/// dependencies, and catches the single-bit flips the fuzz suite injects.
fn fnv1a64(parts: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for part in parts {
        for &b in *part {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Frame layout: `MAGIC(4) | kind(1) | rank(2 LE) | seq(4 LE) |
/// len(4 LE) | payload(len) | fnv1a64(11-byte header + payload)(8 LE)`.
pub fn encode_frame(kind: FrameKind, rank: u16, seq: u32, payload: &[u8]) -> Vec<u8> {
    let mut header = [0u8; 11];
    header[0] = kind as u8;
    header[1..3].copy_from_slice(&rank.to_le_bytes());
    header[3..7].copy_from_slice(&seq.to_le_bytes());
    header[7..11].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    let sum = fnv1a64(&[&header, payload]);
    let mut out = Vec::with_capacity(4 + 11 + payload.len() + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&header);
    out.extend_from_slice(payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Encode and write one frame (single `write_all` so the frame hits the
/// socket as one burst; TCP_NODELAY is set on every stream).
pub fn write_frame<W: Write>(
    w: &mut W,
    kind: FrameKind,
    rank: u16,
    seq: u32,
    payload: &[u8],
) -> Result<(), TransportError> {
    let buf = encode_frame(kind, rank, seq, payload);
    w.write_all(&buf).map_err(|e| map_io(e, "writing frame"))?;
    w.flush().map_err(|e| map_io(e, "flushing frame"))?;
    Ok(())
}

fn read_exact_or(r: &mut impl Read, buf: &mut [u8], got_so_far: usize, want_total: usize)
    -> Result<(), TransportError>
{
    r.read_exact(buf).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            TransportError::Truncated { got: got_so_far, want: want_total }
        } else {
            map_io(e, "reading frame")
        }
    })
}

/// Decode one frame.  Every malformation maps to a typed error:
/// truncation → `Truncated`; bad magic, oversized length, checksum
/// mismatch, unknown kind → `BadFrame`; expired read deadline →
/// `Timeout` (`tests` fuzz all of these).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, TransportError> {
    let mut magic = [0u8; 4];
    read_exact_or(r, &mut magic, 0, 4 + 11)?;
    if magic != MAGIC {
        return Err(TransportError::BadFrame { reason: format!("bad magic {magic:02x?}") });
    }
    let mut header = [0u8; 11];
    read_exact_or(r, &mut header, 4, 4 + 11)?;
    let kind_byte = header[0];
    let rank = u16::from_le_bytes([header[1], header[2]]);
    let seq = u32::from_le_bytes([header[3], header[4], header[5], header[6]]);
    let len = u32::from_le_bytes([header[7], header[8], header[9], header[10]]) as usize;
    if len > MAX_FRAME {
        return Err(TransportError::BadFrame {
            reason: format!("oversized frame: {len} > {MAX_FRAME} bytes"),
        });
    }
    let mut payload = vec![0u8; len];
    read_exact_or(r, &mut payload, 4 + 11, 4 + 11 + len + 8)?;
    let mut sum = [0u8; 8];
    read_exact_or(r, &mut sum, 4 + 11 + len, 4 + 11 + len + 8)?;
    let want = fnv1a64(&[&header, &payload]);
    if u64::from_le_bytes(sum) != want {
        return Err(TransportError::BadFrame { reason: "checksum mismatch".to_string() });
    }
    let kind = FrameKind::from_u8(kind_byte).ok_or_else(|| TransportError::BadFrame {
        reason: format!("unknown frame kind {kind_byte}"),
    })?;
    Ok(Frame { kind, rank, seq, payload })
}

/// Read a frame and require a specific kind (and sequence number, when
/// expected): a structurally valid frame arriving out of protocol order
/// is a `BadFrame`, not a silent misinterpretation.
pub fn expect_frame<R: Read>(
    r: &mut R,
    kind: FrameKind,
    seq: Option<u32>,
) -> Result<Frame, TransportError> {
    let f = read_frame(r)?;
    if f.kind != kind {
        return Err(TransportError::BadFrame {
            reason: format!("expected {kind:?} frame, got {:?} (reordered?)", f.kind),
        });
    }
    if let Some(s) = seq {
        if f.seq != s {
            return Err(TransportError::BadFrame {
                reason: format!("expected {kind:?} seq {s}, got seq {}", f.seq),
            });
        }
    }
    Ok(f)
}

/// f32 → LE bytes.  The wire carries the exact storage bits, so a
/// round-trip is bit-preserving (including negative zero and NaN
/// payloads) — one leg of the cross-transport bitwise-parity argument.
pub fn f32s_to_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// LE bytes → f32 (caller has already validated the length).
pub fn bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

// ---------------------------------------------------------------------
// The fixed binary tree, in both of its equivalent forms
// ---------------------------------------------------------------------

/// The historic in-process reduction: stride loop + copy-out.  At stride
/// `d` the pairs `(i, i+d)` (for `i ≡ 0 mod 2d`, `i+d < e`) combine in
/// increasing-`i` order; afterwards slot 0 holds the sum and is copied
/// to every other slot.  The f32 association order is a function of `e`
/// alone.
pub(crate) fn tree_reduce_inplace(bufs: &mut [Tensor]) {
    let e = bufs.len();
    let mut d = 1;
    while d < e {
        let mut i = 0;
        while i + d < e {
            let (head, tail) = bufs.split_at_mut(i + d);
            head[i].add_assign(&tail[0]);
            i += 2 * d;
        }
        d *= 2;
    }
    let (first, rest) = bufs.split_at_mut(1);
    for b in rest.iter_mut() {
        b.data.copy_from_slice(&first[0].data);
    }
}

/// Binomial-tree children of `rank` in a group of `e`, in the
/// increasing-stride order the rank must consume their partials:
/// `{rank+d : rank ≡ 0 mod 2d, rank+d < e}` for `d = 1, 2, 4, …`.
///
/// Consuming child partials in this order makes each rank's local
/// accumulation replay exactly the stride-loop association of
/// [`tree_reduce_inplace`] (pinned by `tests::binomial_matches_stride_loop`),
/// which is why `LocalTcp` sums are bitwise equal to `InProc` sums.
pub fn children_of(rank: usize, e: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut d = 1;
    while d < e {
        if rank % (2 * d) == 0 && rank + d < e {
            out.push(rank + d);
        }
        d *= 2;
    }
    out
}

/// Binomial-tree parent: clear the lowest set bit.  Rank 0's "parent" is
/// the coordinator itself.
pub fn parent_of(rank: usize) -> usize {
    rank - (rank & rank.wrapping_neg())
}

// ---------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------

/// The pluggable all-reduce data plane.  Implementations move bytes;
/// they never touch clocks, cost models, or stats — that accounting
/// lives in [`Comm`](super::Comm) and is therefore identical across
/// transports by construction.
pub trait Transport: fmt::Debug + Send {
    /// Short name for reports and errors (`"inproc"` / `"tcp"`).
    fn name(&self) -> &'static str;

    /// Reduce `bufs` (one tensor per rank, equal shapes) so every slot
    /// holds the elementwise sum, using the fixed binary-tree order.
    /// `phase` labels the collective for error context only.
    fn all_reduce(&mut self, phase: &str, bufs: &mut [Tensor]) -> Result<(), TransportError>;

    /// Reduce several independent groups.  The default runs them
    /// sequentially; a wire transport may submit all groups before
    /// collecting any result, overlapping the collective waits
    /// (Megatron's column/row-parallel overlap discipline) — the sums
    /// are bitwise identical either way because each group's reduction
    /// order is unchanged.
    fn all_reduce_batch(
        &mut self,
        phase: &str,
        groups: &mut [&mut [Tensor]],
    ) -> Result<(), TransportError> {
        for g in groups.iter_mut() {
            self.all_reduce(phase, g)?;
        }
        Ok(())
    }

    /// Reduce several independent **prefix sub-groups** of one
    /// `e_total`-rank process group (DESIGN.md §18): `groups[i]` spans
    /// ranks `0..groups[i].len()`, with `1 ≤ len ≤ e_total`.  Each
    /// sub-group's f32 association order must equal the fixed stride
    /// loop over its own size — the same order a dedicated group of
    /// that size would use — so mixed-degree sums stay bitwise equal
    /// across transports and thread counts.
    ///
    /// The default reduces each sub-group in place over its own slots
    /// (in-process semantics).  A wire transport over a fixed
    /// `e_total`-rank tree can reuse that tree verbatim: membership
    /// `rank ≡ 0 (mod 2d)` is size-independent, every member's parent
    /// is a member, and non-member subtrees contribute empty payloads
    /// that fold to nothing — so pruning by prefix reproduces the
    /// smaller stride loop bit for bit
    /// (`tests::binomial_prefix_pruning_matches_stride_loop`).
    fn all_reduce_prefix_batch(
        &mut self,
        phase: &str,
        groups: &mut [&mut [Tensor]],
        _e_total: usize,
    ) -> Result<(), TransportError> {
        self.all_reduce_batch(phase, groups)
    }

    /// Make the transport ready for a group of `e` ranks (spawn or
    /// re-spawn worker processes as needed).  A no-op for in-process
    /// transports.  Called by `Trainer::transition_to` after a live
    /// re-shard so churn under `@tcp` rebuilds the process group.
    fn ensure_group(&mut self, _e: usize) -> Result<(), TransportError> {
        Ok(())
    }

    /// Fault injection (tests): SIGKILL the given rank's process.
    /// Returns false when there is no such process to kill.
    fn kill_rank(&mut self, _rank: usize) -> bool {
        false
    }

    /// OS pid of the given rank's process, when one exists.
    fn rank_pid(&self, _rank: usize) -> Option<u32> {
        None
    }
}

/// The historic engine: ranks are buffer slots in the coordinator's
/// address space; the reduction is [`tree_reduce_inplace`], byte for
/// byte today's behavior.
#[derive(Debug, Default, Clone, Copy)]
pub struct InProc;

impl Transport for InProc {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn all_reduce(&mut self, _phase: &str, bufs: &mut [Tensor]) -> Result<(), TransportError> {
        tree_reduce_inplace(bufs);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// LocalTcp: OS-process ranks over localhost sockets
// ---------------------------------------------------------------------

/// Resolve the binary to re-exec as `flextp rank`: explicit config
/// (`--rank-exe`), then the `FLEXTP_RANK_EXE` environment variable
/// (integration tests point it at `CARGO_BIN_EXE_flextp` — the *test*
/// binary is not the CLI), then `current_exe` (the CLI re-execs itself).
pub fn resolve_rank_exe(explicit: Option<&Path>) -> Result<PathBuf, TransportError> {
    if let Some(p) = explicit {
        return Ok(p.to_path_buf());
    }
    if let Ok(p) = std::env::var("FLEXTP_RANK_EXE") {
        if !p.is_empty() {
            return Ok(PathBuf::from(p));
        }
    }
    std::env::current_exe().map_err(|e| TransportError::Io {
        context: "resolving rank executable (current_exe)".to_string(),
        kind: e.kind(),
    })
}

/// Connect with exponential backoff: refused/unreachable attempts retry
/// with doubling sleeps until `deadline_ms` elapses, then the typed
/// `ConnRefused` surfaces.  Rank processes racing the coordinator's (or
/// each other's) listeners is expected at startup, not an error.
pub fn connect_with_backoff(addr: &str, deadline_ms: u64) -> Result<TcpStream, TransportError> {
    let deadline = Instant::now() + Duration::from_millis(deadline_ms);
    let mut sleep_ms = CONNECT_BACKOFF_START_MS;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).map_err(|e| map_io(e, "set_nodelay"))?;
                return Ok(s);
            }
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::ConnectionRefused
                        | io::ErrorKind::ConnectionReset
                        | io::ErrorKind::AddrNotAvailable
                ) =>
            {
                if Instant::now() >= deadline {
                    return Err(TransportError::ConnRefused { addr: addr.to_string() });
                }
                std::thread::sleep(Duration::from_millis(sleep_ms));
                sleep_ms = (sleep_ms * 2).min(CONNECT_BACKOFF_CAP_MS);
            }
            Err(e) => return Err(map_io(e, "connecting")),
        }
    }
}

#[derive(Debug)]
struct RankLink {
    child: Child,
    conn: TcpStream,
}

/// Localhost-TCP transport: the coordinator spawns `e` rank processes,
/// wires them into the fixed binomial tree, and runs every all-reduce
/// as Work frames out / one Sum frame back.  Spawning is lazy (first
/// collective) so constructing a trainer never forks.
#[derive(Debug)]
pub struct LocalTcp {
    timeout: Duration,
    rank_exe: Option<PathBuf>,
    /// Test hook: `(rank, nth)` — that rank parks forever at its nth
    /// Work frame (the self-stall equivalent of SIGSTOP), so the
    /// coordinator's bounded read surfaces a typed `Timeout`.
    stall: Option<(usize, u32)>,
    links: Vec<RankLink>,
    seq: u32,
}

impl LocalTcp {
    pub fn new(timeout_ms: u64, rank_exe: Option<PathBuf>) -> LocalTcp {
        LocalTcp {
            timeout: Duration::from_millis(timeout_ms.max(1)),
            rank_exe,
            stall: None,
            links: Vec::new(),
            seq: 0,
        }
    }

    /// Install the stall fault (must be set before the group spawns).
    pub fn set_stall(&mut self, rank: usize, nth_work_frame: u32) {
        self.stall = Some((rank, nth_work_frame));
    }

    /// Lowest-numbered dead rank, preferring signal-killed processes
    /// (the actual SIGKILL victim) over ranks that exited after the
    /// resulting cascade.
    fn first_dead(&mut self) -> Option<usize> {
        let mut first_exited = None;
        for (r, link) in self.links.iter_mut().enumerate() {
            if let Ok(Some(status)) = link.child.try_wait() {
                #[cfg(unix)]
                {
                    use std::os::unix::process::ExitStatusExt;
                    if status.signal().is_some() {
                        return Some(r);
                    }
                }
                let _ = status;
                if first_exited.is_none() {
                    first_exited = Some(r);
                }
            }
        }
        first_exited
    }

    /// Upgrade a raw transport error using child liveness: if any rank
    /// process is gone, the *real* failure is a dead peer, whatever the
    /// socket reported.  The group is torn down either way — after any
    /// error there may be frames in flight, so the next use respawns.
    fn classify(&mut self, err: TransportError, phase: &str) -> TransportError {
        let out = match err {
            TransportError::BadFrame { .. } | TransportError::PeerDied { .. } => err,
            TransportError::Timeout { .. } => match self.first_dead() {
                Some(rank) => TransportError::PeerDied { rank },
                None => TransportError::Timeout { waiting_for: format!("{phase} all-reduce") },
            },
            other => match self.first_dead() {
                Some(rank) => TransportError::PeerDied { rank },
                None => other,
            },
        };
        self.teardown();
        out
    }

    /// Shut the group down: best-effort Shutdown frames, then SIGKILL +
    /// reap (no zombies, deterministic teardown).
    fn teardown(&mut self) {
        for link in &mut self.links {
            let _ = write_frame(&mut link.conn, FrameKind::Shutdown, 0, 0, &[]);
        }
        for link in &mut self.links {
            let _ = link.child.kill();
            let _ = link.child.wait();
        }
        self.links.clear();
    }

    /// Spawn `e` rank processes and run the handshake: accept `e`
    /// Hellos, push the Topology (parent ports), wait for `e` Readys.
    fn spawn_group(&mut self, e: usize) -> Result<(), TransportError> {
        let exe = resolve_rank_exe(self.rank_exe.as_deref())?;
        let listener = TcpListener::bind("127.0.0.1:0").map_err(|e| map_io(e, "binding"))?;
        let port = listener.local_addr().map_err(|e| map_io(e, "local_addr"))?.port();
        listener.set_nonblocking(true).map_err(|e| map_io(e, "set_nonblocking"))?;

        let mut children: Vec<Child> = Vec::with_capacity(e);
        for i in 0..e {
            let mut cmd = Command::new(&exe);
            cmd.arg("rank")
                .arg("--rank")
                .arg(i.to_string())
                .arg("--e")
                .arg(e.to_string())
                .arg("--connect")
                .arg(format!("127.0.0.1:{port}"))
                .arg("--timeout-ms")
                .arg(RANK_IDLE_TIMEOUT_MS.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::null());
            if let Some((r, n)) = self.stall {
                if r == i {
                    cmd.env("FLEXTP_STALL", n.to_string());
                }
            }
            match cmd.spawn() {
                Ok(c) => children.push(c),
                Err(err) => {
                    for mut c in children {
                        let _ = c.kill();
                        let _ = c.wait();
                    }
                    return Err(TransportError::Io {
                        context: format!("spawning rank {i} ({})", exe.display()),
                        kind: err.kind(),
                    });
                }
            }
        }
        self.links = match Self::handshake(listener, children, e) {
            Ok(links) => links,
            Err(e) => return Err(e),
        };
        for link in &mut self.links {
            link.conn
                .set_read_timeout(Some(self.timeout))
                .map_err(|e| map_io(e, "set_read_timeout"))?;
            link.conn
                .set_write_timeout(Some(self.timeout))
                .map_err(|e| map_io(e, "set_write_timeout"))?;
        }
        self.seq = 0;
        Ok(())
    }

    fn handshake(
        listener: TcpListener,
        mut children: Vec<Child>,
        e: usize,
    ) -> Result<Vec<RankLink>, TransportError> {
        let kill_all = |children: &mut Vec<Child>| {
            for c in children.iter_mut() {
                let _ = c.kill();
                let _ = c.wait();
            }
        };
        let probe_dead = |children: &mut Vec<Child>| -> Option<usize> {
            children
                .iter_mut()
                .position(|c| matches!(c.try_wait(), Ok(Some(_))))
        };
        let deadline = Instant::now() + Duration::from_millis(HANDSHAKE_TIMEOUT_MS);
        let mut conns: Vec<Option<(TcpStream, u16)>> = (0..e).map(|_| None).collect();
        let mut got = 0usize;
        while got < e {
            if Instant::now() >= deadline {
                let dead = probe_dead(&mut children);
                kill_all(&mut children);
                return Err(match dead {
                    Some(rank) => TransportError::PeerDied { rank },
                    None => TransportError::Timeout {
                        waiting_for: format!("hello from {} of {e} rank processes", e - got),
                    },
                });
            }
            match listener.accept() {
                Ok((mut s, _)) => {
                    let setup = (|| -> Result<(usize, TcpStream, u16), TransportError> {
                        s.set_nodelay(true).map_err(|err| map_io(err, "set_nodelay"))?;
                        s.set_read_timeout(Some(Duration::from_millis(HANDSHAKE_TIMEOUT_MS)))
                            .map_err(|err| map_io(err, "set_read_timeout"))?;
                        let f = expect_frame(&mut s, FrameKind::Hello, None)?;
                        let rank = f.rank as usize;
                        if rank >= e || f.payload.len() != 2 {
                            return Err(TransportError::BadFrame {
                                reason: format!("hello from invalid rank {rank} (e={e})"),
                            });
                        }
                        let lp = u16::from_le_bytes([f.payload[0], f.payload[1]]);
                        Ok((rank, s, lp))
                    })();
                    match setup {
                        Ok((rank, s, lp)) if conns[rank].is_none() => {
                            conns[rank] = Some((s, lp));
                            got += 1;
                        }
                        Ok((rank, ..)) => {
                            kill_all(&mut children);
                            return Err(TransportError::BadFrame {
                                reason: format!("duplicate hello from rank {rank}"),
                            });
                        }
                        Err(err) => {
                            let dead = probe_dead(&mut children);
                            kill_all(&mut children);
                            return Err(match dead {
                                Some(rank) => TransportError::PeerDied { rank },
                                None => err,
                            });
                        }
                    }
                }
                Err(err) if err.kind() == io::ErrorKind::WouldBlock => {
                    if let Some(rank) = probe_dead(&mut children) {
                        kill_all(&mut children);
                        return Err(TransportError::PeerDied { rank });
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(err) => {
                    kill_all(&mut children);
                    return Err(map_io(err, "accepting rank connection"));
                }
            }
        }
        // every rank is connected: push the topology, collect the readys
        let ports: Vec<u16> = conns.iter().map(|c| c.as_ref().unwrap().1).collect();
        let mut links: Vec<RankLink> = children
            .into_iter()
            .zip(conns.into_iter().map(Option::unwrap))
            .map(|(child, (conn, _))| RankLink { child, conn })
            .collect();
        let fail = |links: &mut Vec<RankLink>, err: TransportError| -> TransportError {
            let dead = links
                .iter_mut()
                .position(|l| matches!(l.child.try_wait(), Ok(Some(_))));
            for l in links.iter_mut() {
                let _ = l.child.kill();
                let _ = l.child.wait();
            }
            links.clear();
            match dead {
                Some(rank) => TransportError::PeerDied { rank },
                None => err,
            }
        };
        for j in 0..e {
            let parent_port = if j == 0 { 0 } else { ports[parent_of(j)] };
            let mut payload = Vec::with_capacity(4);
            payload.extend_from_slice(&(e as u16).to_le_bytes());
            payload.extend_from_slice(&parent_port.to_le_bytes());
            if let Err(err) =
                write_frame(&mut links[j].conn, FrameKind::Topology, j as u16, 0, &payload)
            {
                return Err(fail(&mut links, err));
            }
        }
        for j in 0..e {
            if let Err(err) = expect_frame(&mut links[j].conn, FrameKind::Ready, None) {
                return Err(fail(&mut links, err));
            }
        }
        Ok(links)
    }
}

impl Transport for LocalTcp {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn ensure_group(&mut self, e: usize) -> Result<(), TransportError> {
        if self.links.len() == e {
            // never silently respawn over a corpse: a dead rank in a
            // right-sized group must surface as the typed PeerDied the
            // recovery path keys on
            if let Some(rank) = self.first_dead() {
                self.teardown();
                return Err(TransportError::PeerDied { rank });
            }
            return Ok(());
        }
        self.teardown();
        self.spawn_group(e)
    }

    fn all_reduce(&mut self, phase: &str, bufs: &mut [Tensor]) -> Result<(), TransportError> {
        self.all_reduce_batch(phase, &mut [bufs])
    }

    /// Submit Work frames for *every* group to *every* rank, then
    /// collect the Sums in group order: the wire work of later groups
    /// overlaps the tree reduction of earlier ones.  Deadlock-free by
    /// topology: the tree has no cycles, ranks consume Work/Partial
    /// frames in a fixed order with blocking reads, and the coordinator
    /// finishes all writes before its first Sum read — a Sum can only
    /// be produced after the inputs it depends on were written.
    fn all_reduce_batch(
        &mut self,
        phase: &str,
        groups: &mut [&mut [Tensor]],
    ) -> Result<(), TransportError> {
        if groups.is_empty() {
            return Ok(());
        }
        let e = groups[0].len();
        self.ensure_group(e)?;
        let seq0 = self.seq;
        self.seq = self.seq.wrapping_add(groups.len() as u32);
        for (gi, g) in groups.iter().enumerate() {
            debug_assert_eq!(g.len(), e, "ragged all-reduce batch");
            let seq = seq0.wrapping_add(gi as u32);
            for r in 0..e {
                let payload = f32s_to_bytes(&g[r].data);
                if let Err(err) =
                    write_frame(&mut self.links[r].conn, FrameKind::Work, r as u16, seq, &payload)
                {
                    return Err(self.classify(err, phase));
                }
            }
        }
        for (gi, g) in groups.iter_mut().enumerate() {
            let seq = seq0.wrapping_add(gi as u32);
            let f = match expect_frame(&mut self.links[0].conn, FrameKind::Sum, Some(seq)) {
                Ok(f) => f,
                Err(err) => return Err(self.classify(err, phase)),
            };
            let want = g[0].data.len() * 4;
            if f.payload.len() != want {
                let reason = format!(
                    "sum length mismatch in {phase}: got {} bytes, want {want}",
                    f.payload.len()
                );
                return Err(self.classify(TransportError::BadFrame { reason }, phase));
            }
            let sum = bytes_to_f32s(&f.payload);
            for b in g.iter_mut() {
                b.data.copy_from_slice(&sum);
            }
        }
        Ok(())
    }

    /// Prefix sub-groups over the `e_total`-rank process tree: members
    /// (`r < g.len()`) get real Work payloads, non-members get
    /// zero-length Work.  Non-member subtrees (all descendants of a
    /// non-member outrank it, hence are non-members too) carry empty
    /// partials that members skip, so each sub-group's sum replays the
    /// stride loop over its own size — bitwise equal to [`InProc`].
    /// Rank 0 is a member of every sub-group, so the Sum frame is
    /// always full-length.
    fn all_reduce_prefix_batch(
        &mut self,
        phase: &str,
        groups: &mut [&mut [Tensor]],
        e_total: usize,
    ) -> Result<(), TransportError> {
        if groups.is_empty() {
            return Ok(());
        }
        if groups.iter().all(|g| g.len() == e_total) {
            // uniform degrees: the historic full-group path, verbatim
            return self.all_reduce_batch(phase, groups);
        }
        self.ensure_group(e_total)?;
        let seq0 = self.seq;
        self.seq = self.seq.wrapping_add(groups.len() as u32);
        for (gi, g) in groups.iter().enumerate() {
            debug_assert!(
                !g.is_empty() && g.len() <= e_total,
                "prefix sub-group of {} outside 1..={e_total}",
                g.len()
            );
            let seq = seq0.wrapping_add(gi as u32);
            for r in 0..e_total {
                let payload =
                    if r < g.len() { f32s_to_bytes(&g[r].data) } else { Vec::new() };
                if let Err(err) =
                    write_frame(&mut self.links[r].conn, FrameKind::Work, r as u16, seq, &payload)
                {
                    return Err(self.classify(err, phase));
                }
            }
        }
        for (gi, g) in groups.iter_mut().enumerate() {
            let seq = seq0.wrapping_add(gi as u32);
            let f = match expect_frame(&mut self.links[0].conn, FrameKind::Sum, Some(seq)) {
                Ok(f) => f,
                Err(err) => return Err(self.classify(err, phase)),
            };
            let want = g[0].data.len() * 4;
            if f.payload.len() != want {
                let reason = format!(
                    "sum length mismatch in {phase}: got {} bytes, want {want}",
                    f.payload.len()
                );
                return Err(self.classify(TransportError::BadFrame { reason }, phase));
            }
            let sum = bytes_to_f32s(&f.payload);
            for b in g.iter_mut() {
                b.data.copy_from_slice(&sum);
            }
        }
        Ok(())
    }

    fn kill_rank(&mut self, rank: usize) -> bool {
        match self.links.get_mut(rank) {
            Some(link) => link.child.kill().is_ok(),
            None => false,
        }
    }

    fn rank_pid(&self, rank: usize) -> Option<u32> {
        self.links.get(rank).map(|l| l.child.id())
    }
}

impl Drop for LocalTcp {
    fn drop(&mut self) {
        self.teardown();
    }
}

// ---------------------------------------------------------------------
// Rank-side protocol loop (the `flextp rank` subcommand)
// ---------------------------------------------------------------------

/// Serve one rank process until Shutdown (clean exit) or a transport
/// error (the caller exits nonzero, and the coordinator's liveness
/// probe converts the cascade into `PeerDied`).
///
/// Protocol: connect to the coordinator (backoff), bind a child-facing
/// listener when this rank has tree children, Hello, read Topology,
/// connect to the parent (rank > 0), accept the children, Ready; then
/// loop — read Work, fold in each child's Partial in increasing-stride
/// order, forward Partial to the parent (or Sum to the coordinator for
/// rank 0).
pub fn rank_serve(rank: usize, e: usize, connect: &str, timeout_ms: u64) -> Result<(), TransportError> {
    if rank >= e || e == 0 {
        return Err(TransportError::BadFrame { reason: format!("rank {rank} outside group of {e}") });
    }
    let stall: Option<u32> = std::env::var("FLEXTP_STALL").ok().and_then(|s| s.parse().ok());
    let idle = Duration::from_millis(timeout_ms.max(1));
    let children = children_of(rank, e);

    let mut coord = connect_with_backoff(connect, CONNECT_DEADLINE_MS)?;
    coord.set_read_timeout(Some(idle)).map_err(|err| map_io(err, "set_read_timeout"))?;

    // child-facing listener (only when the tree gives this rank children)
    let listener = if children.is_empty() {
        None
    } else {
        let l = TcpListener::bind("127.0.0.1:0").map_err(|err| map_io(err, "binding"))?;
        Some(l)
    };
    let listen_port = match &listener {
        Some(l) => l.local_addr().map_err(|err| map_io(err, "local_addr"))?.port(),
        None => 0,
    };
    write_frame(&mut coord, FrameKind::Hello, rank as u16, 0, &listen_port.to_le_bytes())?;

    let topo = expect_frame(&mut coord, FrameKind::Topology, None)?;
    if topo.payload.len() != 4 {
        return Err(TransportError::BadFrame { reason: "malformed topology".to_string() });
    }
    let wire_e = u16::from_le_bytes([topo.payload[0], topo.payload[1]]) as usize;
    let parent_port = u16::from_le_bytes([topo.payload[2], topo.payload[3]]);
    if wire_e != e {
        return Err(TransportError::BadFrame {
            reason: format!("topology says e={wire_e}, spawned with e={e}"),
        });
    }

    // upstream link: parent rank (via its listener) or the coordinator
    let mut parent = if rank > 0 {
        let mut p = connect_with_backoff(&format!("127.0.0.1:{parent_port}"), CONNECT_DEADLINE_MS)?;
        p.set_read_timeout(Some(idle)).map_err(|err| map_io(err, "set_read_timeout"))?;
        write_frame(&mut p, FrameKind::Hello, rank as u16, 0, &0u16.to_le_bytes())?;
        Some(p)
    } else {
        None
    };

    // downstream links, identified by the Hello each child sends
    let mut child_conns: Vec<Option<TcpStream>> = (0..children.len()).map(|_| None).collect();
    if let Some(listener) = &listener {
        let mut got = 0;
        while got < children.len() {
            let (mut s, _) = listener.accept().map_err(|err| map_io(err, "accepting child"))?;
            s.set_nodelay(true).map_err(|err| map_io(err, "set_nodelay"))?;
            s.set_read_timeout(Some(idle)).map_err(|err| map_io(err, "set_read_timeout"))?;
            let hello = expect_frame(&mut s, FrameKind::Hello, None)?;
            let who = hello.rank as usize;
            let slot = children.iter().position(|&c| c == who).ok_or_else(|| {
                TransportError::BadFrame {
                    reason: format!("rank {who} is not a tree child of rank {rank}"),
                }
            })?;
            if child_conns[slot].is_some() {
                return Err(TransportError::BadFrame {
                    reason: format!("duplicate child connection from rank {who}"),
                });
            }
            child_conns[slot] = Some(s);
            got += 1;
        }
    }
    let mut child_conns: Vec<TcpStream> = child_conns.into_iter().map(Option::unwrap).collect();

    write_frame(&mut coord, FrameKind::Ready, rank as u16, 0, &[])?;

    // steady state
    let mut works_seen: u32 = 0;
    loop {
        let frame = read_frame(&mut coord)?;
        match frame.kind {
            FrameKind::Shutdown => return Ok(()),
            FrameKind::Work => {}
            other => {
                return Err(TransportError::BadFrame {
                    reason: format!("rank {rank} expected Work/Shutdown, got {other:?}"),
                })
            }
        }
        works_seen += 1;
        if let Some(n) = stall {
            if works_seen >= n {
                // SIGSTOP equivalent: stop responding forever; the
                // coordinator's bounded read reports the typed Timeout
                loop {
                    std::thread::sleep(Duration::from_secs(3600));
                }
            }
        }
        let seq = frame.seq;
        let mut acc = bytes_to_f32s(&frame.payload);
        for conn in child_conns.iter_mut() {
            let part = expect_frame(conn, FrameKind::Partial, Some(seq))?;
            if part.payload.is_empty() {
                // prefix sub-group collective (DESIGN.md §18): the child
                // heads a non-member subtree and contributes nothing
                continue;
            }
            if part.payload.len() != frame.payload.len() {
                return Err(TransportError::BadFrame {
                    reason: format!(
                        "partial length mismatch at rank {rank}: got {}, want {}",
                        part.payload.len(),
                        frame.payload.len()
                    ),
                });
            }
            for (a, b) in acc.iter_mut().zip(bytes_to_f32s(&part.payload)) {
                *a += b;
            }
        }
        let out = f32s_to_bytes(&acc);
        match &mut parent {
            Some(p) => write_frame(p, FrameKind::Partial, rank as u16, seq, &out)?,
            None => write_frame(&mut coord, FrameKind::Sum, rank as u16, seq, &out)?,
        }
    }
}

// ---------------------------------------------------------------------
// Tests: codec round-trips, seeded frame fuzz, tree equivalence
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::io::Cursor;

    const CASES: usize = 40;

    fn rand_payload(rng: &mut Rng, max: usize) -> Vec<u8> {
        let n = rng.below(max + 1);
        (0..n).map(|_| rng.below(256) as u8).collect()
    }

    #[test]
    fn roundtrip_all_frame_kinds() {
        for seed in 0..CASES as u64 {
            let mut rng = Rng::new(seed ^ 0x7a11);
            for kind in FrameKind::all() {
                let rank = rng.below(1 << 16) as u16;
                let seq = rng.below(1 << 30) as u32;
                let payload = rand_payload(&mut rng, 512);
                let bytes = encode_frame(kind, rank, seq, &payload);
                let got = read_frame(&mut Cursor::new(&bytes)).expect("round-trip");
                assert_eq!(got, Frame { kind, rank, seq, payload });
            }
        }
    }

    #[test]
    fn f32_payloads_roundtrip_bitwise() {
        for seed in 0..CASES as u64 {
            let mut rng = Rng::new(seed ^ 0xf32);
            let n = 1 + rng.below(300);
            let mut vals: Vec<f32> = (0..n).map(|_| rng.normal() * 1e3).collect();
            // exotic bit patterns must survive too
            vals[0] = -0.0;
            if n > 1 {
                vals[1] = f32::MIN_POSITIVE / 2.0; // subnormal
            }
            let back = bytes_to_f32s(&f32s_to_bytes(&vals));
            let a: Vec<u32> = vals.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = back.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "f32 wire round-trip must be bit-exact");
        }
    }

    #[test]
    fn fuzz_truncated_frames_are_typed() {
        for seed in 0..CASES as u64 {
            let mut rng = Rng::new(seed ^ 0x77);
            let payload = rand_payload(&mut rng, 256);
            let bytes = encode_frame(FrameKind::Work, 3, 9, &payload);
            let cut = rng.below(bytes.len()); // strictly shorter
            let err = read_frame(&mut Cursor::new(&bytes[..cut])).unwrap_err();
            assert!(
                matches!(err, TransportError::Truncated { .. }),
                "cut at {cut}/{} gave {err:?}",
                bytes.len()
            );
        }
    }

    #[test]
    fn fuzz_bitflips_are_typed() {
        // a flipped bit anywhere decodes to a typed error, never Ok with
        // silently corrupt content (checksum covers header + payload)
        for seed in 0..CASES as u64 {
            let mut rng = Rng::new(seed ^ 0xb17);
            let payload = rand_payload(&mut rng, 256);
            let clean = encode_frame(FrameKind::Partial, 1, 7, &payload);
            let mut bytes = clean.clone();
            let pos = rng.below(bytes.len());
            let bit = 1u8 << rng.below(8);
            bytes[pos] ^= bit;
            match read_frame(&mut Cursor::new(&bytes)) {
                Err(
                    TransportError::BadFrame { .. }
                    | TransportError::Truncated { .. }
                    | TransportError::Timeout { .. },
                ) => {}
                Err(other) => panic!("flip at byte {pos} gave untyped-ish {other:?}"),
                Ok(f) => {
                    // the only acceptable Ok is the length field shrinking
                    // onto a frame whose checksum still validates — FNV
                    // makes that effectively impossible; fail loudly
                    panic!("flip at byte {pos} decoded Ok: {f:?}")
                }
            }
        }
    }

    #[test]
    fn fuzz_oversized_frames_are_typed() {
        for seed in 0..CASES as u64 {
            let mut rng = Rng::new(seed ^ 0x0ababa);
            let mut bytes = encode_frame(FrameKind::Work, 0, 0, &[1, 2, 3]);
            let huge = (MAX_FRAME as u32) + 1 + rng.below(1 << 20) as u32;
            bytes[11..15].copy_from_slice(&huge.to_le_bytes());
            let err = read_frame(&mut Cursor::new(&bytes)).unwrap_err();
            assert!(
                matches!(err, TransportError::BadFrame { ref reason } if reason.contains("oversized")),
                "got {err:?}"
            );
        }
    }

    #[test]
    fn fuzz_reordered_frames_are_typed() {
        for seed in 0..CASES as u64 {
            let mut rng = Rng::new(seed ^ 0x5e9);
            // a valid frame of the wrong kind, or the right kind with the
            // wrong sequence number, must be rejected as BadFrame
            let kinds = FrameKind::all();
            let kind = kinds[rng.below(kinds.len())];
            let seq = rng.below(100) as u32;
            let bytes = encode_frame(kind, 2, seq, &[0xAB; 8]);
            let want_kind = FrameKind::Sum;
            let want_seq = seq + 1;
            let err = expect_frame(&mut Cursor::new(&bytes), want_kind, Some(want_seq)).unwrap_err();
            assert!(matches!(err, TransportError::BadFrame { .. }), "got {err:?}");
        }
    }

    #[test]
    fn bad_magic_and_unknown_kind_are_typed() {
        let mut bytes = encode_frame(FrameKind::Ready, 0, 0, &[]);
        bytes[0] = b'N';
        assert!(matches!(
            read_frame(&mut Cursor::new(&bytes)).unwrap_err(),
            TransportError::BadFrame { .. }
        ));
        // unknown kind with a *recomputed valid checksum* still rejects
        let payload: &[u8] = &[9, 9];
        let mut header = [0u8; 11];
        header[0] = 250; // no such kind
        header[7..11].copy_from_slice(&(payload.len() as u32).to_le_bytes());
        let sum = fnv1a64(&[&header, payload]);
        let mut raw = Vec::new();
        raw.extend_from_slice(&MAGIC);
        raw.extend_from_slice(&header);
        raw.extend_from_slice(payload);
        raw.extend_from_slice(&sum.to_le_bytes());
        assert!(matches!(
            read_frame(&mut Cursor::new(&raw)).unwrap_err(),
            TransportError::BadFrame { ref reason } if reason.contains("unknown frame kind")
        ));
    }

    #[test]
    fn tree_shape_is_consistent() {
        // children/parent must describe the same tree, rooted at 0
        for e in 1..=17 {
            for j in 1..e {
                let p = parent_of(j);
                assert!(p < j, "parent must be lower-numbered");
                assert!(
                    children_of(p, e).contains(&j),
                    "rank {j} missing from children of {p} (e={e})"
                );
            }
            let mut seen = vec![false; e];
            seen[0] = true;
            let mut frontier = vec![0usize];
            while let Some(r) = frontier.pop() {
                for c in children_of(r, e) {
                    assert!(!seen[c], "rank {c} reached twice (e={e})");
                    seen[c] = true;
                    frontier.push(c);
                }
            }
            assert!(seen.iter().all(|&s| s), "tree must span all ranks (e={e})");
        }
    }

    /// The bitwise-parity keystone: simulating the binomial tree (each
    /// rank folds child partials in increasing-stride order, parents
    /// fold in post-order) reproduces the stride-loop sums **bit for
    /// bit** for every group size — the exact computation `LocalTcp`
    /// distributes across processes.
    #[test]
    fn binomial_matches_stride_loop() {
        fn binomial_sum(rank: usize, e: usize, inputs: &[Vec<f32>]) -> Vec<f32> {
            let mut acc = inputs[rank].clone();
            for c in children_of(rank, e) {
                let part = binomial_sum(c, e, inputs);
                for (a, b) in acc.iter_mut().zip(part) {
                    *a += b;
                }
            }
            acc
        }
        for seed in 0..CASES as u64 {
            let mut rng = Rng::new(seed ^ 0xb1_70);
            for e in 1..=9 {
                let n = 1 + rng.below(64);
                let inputs: Vec<Vec<f32>> =
                    (0..e).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
                let mut bufs: Vec<Tensor> =
                    inputs.iter().map(|v| Tensor::from_vec(&[n], v.clone())).collect();
                tree_reduce_inplace(&mut bufs);
                let wire = binomial_sum(0, e, &inputs);
                let a: Vec<u32> = bufs[0].data.iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = wire.iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "binomial ≠ stride loop at e={e}, n={n}");
            }
        }
    }

    /// The mixed-degree keystone (DESIGN.md §18): pruning the fixed
    /// `e_total` binomial tree to a rank prefix `0..g` — zero-length
    /// payloads for non-members, empty partials skipped — reproduces the
    /// `g`-sized stride loop bit for bit, for every (e_total, g).  This
    /// is the exact computation `LocalTcp::all_reduce_prefix_batch`
    /// distributes across processes.
    #[test]
    fn binomial_prefix_pruning_matches_stride_loop() {
        fn prefix_sum(rank: usize, e_total: usize, g: usize, inputs: &[Vec<f32>]) -> Vec<f32> {
            let mut acc = if rank < g { inputs[rank].clone() } else { Vec::new() };
            for c in children_of(rank, e_total) {
                let part = prefix_sum(c, e_total, g, inputs);
                if part.is_empty() {
                    continue;
                }
                assert!(
                    !acc.is_empty(),
                    "non-member rank {rank} got a non-empty partial (g={g}, e={e_total})"
                );
                for (a, b) in acc.iter_mut().zip(part) {
                    *a += b;
                }
            }
            acc
        }
        for seed in 0..CASES as u64 {
            let mut rng = Rng::new(seed ^ 0x9f17);
            for e_total in 1..=9 {
                for g in 1..=e_total {
                    let n = 1 + rng.below(48);
                    let inputs: Vec<Vec<f32>> =
                        (0..g).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
                    let mut bufs: Vec<Tensor> =
                        inputs.iter().map(|v| Tensor::from_vec(&[n], v.clone())).collect();
                    tree_reduce_inplace(&mut bufs);
                    let wire = prefix_sum(0, e_total, g, &inputs);
                    let a: Vec<u32> = bufs[0].data.iter().map(|v| v.to_bits()).collect();
                    let b: Vec<u32> = wire.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(a, b, "prefix tree ≠ stride loop at e={e_total}, g={g}, n={n}");
                }
            }
        }
    }

    #[test]
    fn prefix_batch_default_reduces_each_group_over_its_own_size() {
        // the trait default (InProc semantics) reduces ragged sub-groups
        // independently, matching per-group stride loops bitwise
        let mut t = InProc;
        let mut a = vec![
            Tensor::from_vec(&[2], vec![1.0, 2.0]),
            Tensor::from_vec(&[2], vec![10.0, 20.0]),
        ];
        let mut b = vec![
            Tensor::from_vec(&[2], vec![1.0, 1.0]),
            Tensor::from_vec(&[2], vec![2.0, 2.0]),
            Tensor::from_vec(&[2], vec![3.0, 3.0]),
            Tensor::from_vec(&[2], vec![4.0, 4.0]),
        ];
        t.all_reduce_prefix_batch("test", &mut [&mut a[..], &mut b[..]], 4).unwrap();
        for s in &a {
            assert_eq!(s.data, vec![11.0, 22.0]);
        }
        for s in &b {
            assert_eq!(s.data, vec![10.0, 10.0]);
        }
    }

    #[test]
    fn inproc_transport_is_the_stride_loop() {
        let mut t = InProc;
        let mut bufs = vec![
            Tensor::from_vec(&[2], vec![1.0, 2.0]),
            Tensor::from_vec(&[2], vec![10.0, 20.0]),
            Tensor::from_vec(&[2], vec![100.0, 200.0]),
        ];
        t.all_reduce("test", &mut bufs).unwrap();
        for b in &bufs {
            assert_eq!(b.data, vec![111.0, 222.0]);
        }
    }

    #[test]
    fn batch_default_equals_sequential() {
        let mk = || {
            vec![
                Tensor::from_vec(&[2], vec![0.1, 0.2]),
                Tensor::from_vec(&[2], vec![0.3, 0.4]),
            ]
        };
        let mut a1 = mk();
        let mut a2 = mk();
        let mut b1 = mk();
        let mut b2 = mk();
        let mut t = InProc;
        t.all_reduce_batch("test", &mut [&mut a1[..], &mut a2[..]]).unwrap();
        t.all_reduce("test", &mut b1).unwrap();
        t.all_reduce("test", &mut b2).unwrap();
        assert_eq!(a1[0].data, b1[0].data);
        assert_eq!(a2[0].data, b2[0].data);
    }

    #[test]
    fn errors_display_and_are_std_errors() {
        let errs: Vec<TransportError> = vec![
            TransportError::ConnRefused { addr: "127.0.0.1:1".into() },
            TransportError::Truncated { got: 3, want: 15 },
            TransportError::BadFrame { reason: "x".into() },
            TransportError::PeerDied { rank: 2 },
            TransportError::Timeout { waiting_for: "sum".into() },
            TransportError::Io { context: "y".into(), kind: io::ErrorKind::BrokenPipe },
        ];
        for e in errs {
            let boxed: Box<dyn std::error::Error> = Box::new(e.clone());
            assert!(!boxed.to_string().is_empty());
        }
    }
}
