//! α-β (latency-bandwidth) interconnect cost model.
//!
//! `time(bytes) = α + bytes/β` per point-to-point transfer.  Collective
//! algorithms compose transfers:
//!   * ring all-reduce  — 2(e-1) steps of `bytes/e` chunks (NCCL-style)
//!   * ring all-gather  — (e-1) steps of `bytes/e`
//!   * tree bcast/reduce — ⌈log₂ n⌉ rounds of the full payload; already-
//!     served nodes relay, which is precisely the paper's argument for
//!     choosing broadcast-reduce over scatter-gather (§IV-A)
//!   * flat p2p         — one full-payload transfer (scatter/gather legs)
//!
//! Defaults approximate the paper's PCIe 3.0 testbed; benches also sweep
//! these to show where the Table I crossover moves.

#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// per-transfer latency (seconds)
    pub alpha_s: f64,
    /// bandwidth (bytes/second)
    pub bytes_per_s: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { alpha_s: 10e-6, bytes_per_s: 12e9 }
    }
}

impl CostModel {
    pub fn from_net(net: crate::config::NetCfg) -> CostModel {
        CostModel { alpha_s: net.alpha_s, bytes_per_s: net.bytes_per_s }
    }

    /// One point-to-point transfer.
    pub fn p2p(&self, bytes: usize) -> f64 {
        self.alpha_s + bytes as f64 / self.bytes_per_s
    }

    /// Ring all-reduce over e ranks: 2(e-1) chunk steps.
    pub fn ring_allreduce(&self, e: usize, bytes: usize) -> f64 {
        if e <= 1 {
            return 0.0;
        }
        let steps = 2 * (e - 1);
        steps as f64 * (self.alpha_s + bytes as f64 / e as f64 / self.bytes_per_s)
    }

    /// Ring all-gather over e ranks: (e-1) chunk steps.
    pub fn ring_allgather(&self, e: usize, total_bytes: usize) -> f64 {
        if e <= 1 {
            return 0.0;
        }
        let steps = e - 1;
        steps as f64 * (self.alpha_s + total_bytes as f64 / e as f64 / self.bytes_per_s)
    }

    /// Binomial-tree rounds over n nodes: ⌈log₂ n⌉ full-payload rounds.
    pub fn tree_rounds(&self, n: usize, bytes: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let rounds = (usize::BITS - (n - 1).leading_zeros()) as f64; // ceil(log2 n)
        rounds * (self.alpha_s + bytes as f64 / self.bytes_per_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel { alpha_s: 1e-6, bytes_per_s: 1e9 }
    }

    #[test]
    fn p2p_is_affine() {
        let c = cm();
        assert!((c.p2p(0) - 1e-6).abs() < 1e-12);
        assert!((c.p2p(1_000_000) - (1e-6 + 1e-3)).abs() < 1e-9);
    }

    #[test]
    fn tree_log_rounds() {
        let c = cm();
        // n=2 → 1 round, n=8 → 3 rounds, n=9 → 4 rounds
        assert!((c.tree_rounds(2, 0) - 1e-6).abs() < 1e-12);
        assert!((c.tree_rounds(8, 0) - 3e-6).abs() < 1e-12);
        assert!((c.tree_rounds(9, 0) - 4e-6).abs() < 1e-12);
        assert_eq!(c.tree_rounds(1, 1000), 0.0);
    }

    #[test]
    fn ring_allreduce_scales_with_e() {
        let c = cm();
        assert_eq!(c.ring_allreduce(1, 1000), 0.0);
        // bandwidth term ~2·bytes/β independent of e (asymptotically)
        let t2 = c.ring_allreduce(2, 1 << 20);
        let t8 = c.ring_allreduce(8, 1 << 20);
        let bw = 2.0 * (1u64 << 20) as f64 / 1e9;
        assert!((t2 - (2.0 * 1e-6 + bw / 2.0 * 1.0)).abs() < 1e-9);
        assert!(t8 < 2.0 * bw); // bounded by ~2x bandwidth term
    }

    #[test]
    fn tree_beats_flat_fanout_for_large_groups() {
        let c = cm();
        let n = 16;
        let bytes = 1 << 20;
        let flat = (n - 1) as f64 * c.p2p(bytes);
        assert!(c.tree_rounds(n, bytes) < flat);
    }
}
