//! Model state: per-worker 1D-TP parameter shards + replicated params.
//!
//! Shard layout matches `python/compile/model.py` (column-then-row split):
//! `wqkv [hs, 3·hsl]`, `wo [hsl, hs]`, `w1 [hs, ffl]`, `w2 [ffl, hs]`;
//! LN/embed/head replicated.  Replicated replicas stay bit-identical
//! across workers because their gradients are all-reduced and the
//! optimizer update is deterministic — `trainer` asserts this invariant.

use anyhow::{Context, Result};

use crate::runtime::manifest::ModelInfo;
use crate::tensor::Tensor;
use crate::util::bin::Bundle;
use crate::util::rng::Rng;

/// One transformer block's per-worker shard.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockShard {
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    pub wqkv: Tensor,
    pub wo: Tensor,
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
    pub w1: Tensor,
    pub w2: Tensor,
}

impl BlockShard {
    pub fn names() -> [&'static str; 8] {
        ["ln1_g", "ln1_b", "wqkv", "wo", "ln2_g", "ln2_b", "w1", "w2"]
    }

    pub fn get(&self, name: &str) -> &Tensor {
        match name {
            "ln1_g" => &self.ln1_g,
            "ln1_b" => &self.ln1_b,
            "wqkv" => &self.wqkv,
            "wo" => &self.wo,
            "ln2_g" => &self.ln2_g,
            "ln2_b" => &self.ln2_b,
            "w1" => &self.w1,
            "w2" => &self.w2,
            _ => panic!("unknown block tensor '{name}'"),
        }
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        match name {
            "ln1_g" => &mut self.ln1_g,
            "ln1_b" => &mut self.ln1_b,
            "wqkv" => &mut self.wqkv,
            "wo" => &mut self.wo,
            "ln2_g" => &mut self.ln2_g,
            "ln2_b" => &mut self.ln2_b,
            "w1" => &mut self.w1,
            "w2" => &mut self.w2,
            _ => panic!("unknown block tensor '{name}'"),
        }
    }
}

/// Replicated (unsharded) parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct RepParams {
    pub w_patch: Tensor,
    pub pos: Tensor,
    pub cls: Tensor,
    pub lnf_g: Tensor,
    pub lnf_b: Tensor,
    pub w_head: Tensor,
    pub b_head: Tensor,
}

impl RepParams {
    pub fn names() -> [&'static str; 7] {
        ["w_patch", "pos", "cls", "lnf_g", "lnf_b", "w_head", "b_head"]
    }

    pub fn get(&self, name: &str) -> &Tensor {
        match name {
            "w_patch" => &self.w_patch,
            "pos" => &self.pos,
            "cls" => &self.cls,
            "lnf_g" => &self.lnf_g,
            "lnf_b" => &self.lnf_b,
            "w_head" => &self.w_head,
            "b_head" => &self.b_head,
            _ => panic!("unknown rep tensor '{name}'"),
        }
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        match name {
            "w_patch" => &mut self.w_patch,
            "pos" => &mut self.pos,
            "cls" => &mut self.cls,
            "lnf_g" => &mut self.lnf_g,
            "lnf_b" => &mut self.lnf_b,
            "w_head" => &mut self.w_head,
            "b_head" => &mut self.b_head,
            _ => panic!("unknown rep tensor '{name}'"),
        }
    }
}

/// Full model state: per-worker block shards + one replicated set.
#[derive(Debug, Clone)]
pub struct ModelState {
    /// `shards[w][k]` = worker w's shard of block k
    pub shards: Vec<Vec<BlockShard>>,
    pub rep: RepParams,
}

const INIT_STD: f32 = 0.02;

impl ModelState {
    /// Fresh ViT init.  Per-(worker, block) seeds keep shard init
    /// independent; replicated params use a shared seed stream.
    pub fn init(m: &ModelInfo, seed: u64) -> ModelState {
        let mut shards = Vec::with_capacity(m.e);
        for w in 0..m.e {
            let mut blocks = Vec::with_capacity(m.depth);
            for k in 0..m.depth {
                let mut rng = Rng::new(seed ^ (0x5151 + (w * 1009 + k) as u64));
                blocks.push(BlockShard {
                    ln1_g: Tensor::full(&[m.hs], 1.0),
                    ln1_b: Tensor::zeros(&[m.hs]),
                    wqkv: Tensor::normal(&[m.hs, 3 * m.hsl], INIT_STD, &mut rng),
                    wo: Tensor::normal(&[m.hsl, m.hs], INIT_STD, &mut rng),
                    ln2_g: Tensor::full(&[m.hs], 1.0),
                    ln2_b: Tensor::zeros(&[m.hs]),
                    w1: Tensor::normal(&[m.hs, m.ffl], INIT_STD, &mut rng),
                    w2: Tensor::normal(&[m.ffl, m.hs], INIT_STD, &mut rng),
                });
            }
            shards.push(blocks);
        }
        let mut rng = Rng::new(seed ^ 0xA11CE);
        let rep = RepParams {
            w_patch: Tensor::normal(&[m.pd, m.hs], INIT_STD, &mut rng),
            pos: Tensor::zeros(&[m.seq, m.hs]),
            cls: Tensor::zeros(&[m.hs]),
            lnf_g: Tensor::full(&[m.hs], 1.0),
            lnf_b: Tensor::zeros(&[m.hs]),
            w_head: Tensor::normal(&[m.hs, m.classes], INIT_STD, &mut rng),
            b_head: Tensor::zeros(&[m.classes]),
        };
        ModelState { shards, rep }
    }

    /// Load the golden bundle's parameter snapshot (cross-language test).
    pub fn from_bundle(m: &ModelInfo, bundle: &Bundle) -> Result<ModelState> {
        let mut shards = Vec::with_capacity(m.e);
        for w in 0..m.e {
            let mut blocks = Vec::with_capacity(m.depth);
            for k in 0..m.depth {
                let load = |n: &str| -> Result<Tensor> {
                    let e = bundle.get(&format!("params.{w}.blk{k}.{n}"))?;
                    Ok(Tensor::from_vec(&e.dims, e.f32()?.to_vec()))
                };
                blocks.push(BlockShard {
                    ln1_g: load("ln1_g")?,
                    ln1_b: load("ln1_b")?,
                    wqkv: load("wqkv")?,
                    wo: load("wo")?,
                    ln2_g: load("ln2_g")?,
                    ln2_b: load("ln2_b")?,
                    w1: load("w1")?,
                    w2: load("w2")?,
                });
            }
            shards.push(blocks);
        }
        let load = |n: &str| -> Result<Tensor> {
            let e = bundle.get(&format!("params.rep.{n}"))?;
            Ok(Tensor::from_vec(&e.dims, e.f32()?.to_vec()))
        };
        Ok(ModelState {
            shards,
            rep: RepParams {
                w_patch: load("w_patch")?,
                pos: load("pos")?,
                cls: load("cls")?,
                lnf_g: load("lnf_g")?,
                lnf_b: load("lnf_b")?,
                w_head: load("w_head")?,
                b_head: load("b_head")?,
            },
        })
    }

    pub fn e(&self) -> usize {
        self.shards.len()
    }

    pub fn depth(&self) -> usize {
        self.shards.first().map(|b| b.len()).unwrap_or(0)
    }

    /// Total parameter count (shards + one replica).
    pub fn param_count(&self) -> usize {
        let shard: usize = self
            .shards
            .iter()
            .flat_map(|bs| bs.iter())
            .map(|b| BlockShard::names().iter().map(|n| b.get(n).len()).sum::<usize>())
            .sum();
        let rep: usize =
            RepParams::names().iter().map(|n| self.rep.get(n).len()).sum();
        shard + rep
    }
}

/// Gradients for one block shard (same shapes as [`BlockShard`]).
pub type BlockGrads = BlockShard;

/// Gradients for the replicated params.
pub type RepGrads = RepParams;

/// TP group size owning a block-shard tensor under fine-grained degrees
/// (DESIGN.md §18): attention tensors (`ln1_*`, `wqkv`, `wo`) belong to
/// the `degrees.attn` group, MLP tensors (`ln2_*`, `w1`, `w2`) to the
/// `degrees.mlp` group.  Ranks `>= shard_degree(m, name)` hold
/// zero-filled slots for that tensor and never compute with it.
pub fn shard_degree(m: &ModelInfo, name: &str) -> usize {
    match name {
        "ln1_g" | "ln1_b" | "wqkv" | "wo" => m.degrees.attn,
        "ln2_g" | "ln2_b" | "w1" | "w2" => m.degrees.mlp,
        _ => panic!("unknown block tensor '{name}'"),
    }
}

pub fn zero_block_grads(m: &ModelInfo) -> BlockGrads {
    BlockShard {
        ln1_g: Tensor::zeros(&[m.hs]),
        ln1_b: Tensor::zeros(&[m.hs]),
        wqkv: Tensor::zeros(&[m.hs, 3 * m.hsl]),
        wo: Tensor::zeros(&[m.hsl, m.hs]),
        ln2_g: Tensor::zeros(&[m.hs]),
        ln2_b: Tensor::zeros(&[m.hs]),
        w1: Tensor::zeros(&[m.hs, m.ffl]),
        w2: Tensor::zeros(&[m.ffl, m.hs]),
    }
}

/// Verify the golden bundle's shapes agree with the manifest — guards the
/// python/rust contract.
pub fn check_bundle_shapes(m: &ModelInfo, bundle: &Bundle) -> Result<()> {
    let e = bundle.get("params.0.blk0.wqkv").context("bundle missing shard params")?;
    anyhow::ensure!(
        e.dims == vec![m.hs, 3 * m.hsl],
        "wqkv bundle dims {:?} != manifest [{}, {}]", e.dims, m.hs, 3 * m.hsl
    );
    let p = bundle.get("batch.patches")?;
    anyhow::ensure!(
        p.dims == vec![m.bs, m.seq0, m.pd],
        "patches dims {:?} mismatch", p.dims
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_info() -> ModelInfo {
        ModelInfo {
            name: "t".into(), hs: 32, depth: 2, heads: 4, e: 4, bs: 2,
            classes: 10, seq: 17, seq0: 16, pd: 48, hsl: 8, hl: 1, hd: 8,
            ffl: 32, params_total: 0, params_per_worker: 0,
            degrees: crate::runtime::manifest::Degrees::uniform(4),
        }
    }

    #[test]
    fn init_shapes() {
        let m = tiny_info();
        let s = ModelState::init(&m, 1);
        assert_eq!(s.e(), 4);
        assert_eq!(s.depth(), 2);
        assert_eq!(s.shards[0][0].wqkv.dims, vec![32, 24]);
        assert_eq!(s.shards[0][0].w2.dims, vec![32, 32]);
        assert_eq!(s.rep.w_head.dims, vec![32, 10]);
    }

    #[test]
    fn init_deterministic_and_shard_distinct() {
        let m = tiny_info();
        let a = ModelState::init(&m, 1);
        let b = ModelState::init(&m, 1);
        assert_eq!(a.shards[0][0].wqkv.data, b.shards[0][0].wqkv.data);
        // different workers get different shards
        assert_ne!(a.shards[0][0].wqkv.data, a.shards[1][0].wqkv.data);
        // different seeds differ
        let c = ModelState::init(&m, 2);
        assert_ne!(a.shards[0][0].wqkv.data, c.shards[0][0].wqkv.data);
    }

    #[test]
    fn param_count_matches_formula() {
        let m = tiny_info();
        let s = ModelState::init(&m, 1);
        let blk = 4 * 32 + 32 * 24 + 8 * 32 + 32 * 32 + 32 * 32;
        let rep = 48 * 32 + 17 * 32 + 32 + 2 * 32 + 32 * 10 + 10;
        assert_eq!(s.param_count(), 4 * 2 * blk + rep);
    }

    #[test]
    fn name_accessors_roundtrip() {
        let m = tiny_info();
        let mut s = ModelState::init(&m, 1);
        for n in BlockShard::names() {
            let dims = s.shards[0][0].get(n).dims.clone();
            s.shards[0][0].get_mut(n).fill(1.0);
            assert_eq!(s.shards[0][0].get(n).dims, dims);
        }
        for n in RepParams::names() {
            assert!(!s.rep.get(n).is_empty());
        }
    }
}
