//! Training layer: SGD optimizer, the Algorithm-2 pretest, the scoped
//! rank-execution pool ([`parallel`]), and the lock-step
//! [`trainer::Trainer`] engine.

pub mod parallel;
pub mod trainer;

use std::collections::BTreeMap;

use crate::collectives::cost::CostModel;
use crate::runtime::manifest::ModelInfo;
use crate::semi::CostFns;
use crate::tensor::Tensor;

/// SGD with optional momentum. Buffers are keyed by a stable string id
/// ("<worker>.<block>.<name>" / "rep.<name>"), created on first use.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    pub(crate) bufs: BTreeMap<String, Tensor>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Sgd {
        Sgd { lr, momentum, bufs: BTreeMap::new() }
    }

    /// p ← p − lr·(m·v + g); v ← m·v + g  (plain SGD when momentum = 0,
    /// matching the golden bundle's reference update).
    pub fn update(&mut self, key: &str, param: &mut Tensor, grad: &Tensor) {
        if self.momentum == 0.0 {
            param.sub_scaled(grad, self.lr);
            return;
        }
        let v = self
            .bufs
            .entry(key.to_string())
            .or_insert_with(|| Tensor::zeros(&grad.dims));
        for (vi, gi) in v.data.iter_mut().zip(&grad.data) {
            *vi = self.momentum * *vi + gi;
        }
        param.sub_scaled(v, self.lr);
    }

    pub fn buffer_count(&self) -> usize {
        self.bufs.len()
    }
}

/// Build the SEMI cost functions (paper Algorithm 2 line 1, "pretest").
///
/// * Ω₁/Ω₂ — measured on this host: submatrix allocation and per-column
///   extraction (gather) cost at representative sizes.
/// * Φ₁ — from the α-β interconnect model: per iteration, a migrated
///   column costs a tree-broadcast of its 2·hs weight values out plus a
///   flat gather of its 2·hs compact gradient values back, per layer.
/// * Φ₂ — from the measured full-FFN executable time: receiver compute
///   scales linearly in migrated columns.
pub fn pretest(
    m: &ModelInfo,
    net: &CostModel,
    mlp_fwd_bwd_secs: f64,
) -> CostFns {
    // Ω₁: allocate a half-size [hs, ffl/2] submatrix a few times
    let t0 = std::time::Instant::now();
    const REPS: usize = 8;
    for _ in 0..REPS {
        let t = Tensor::zeros(&[m.hs, (m.ffl / 2).max(1)]);
        std::hint::black_box(&t);
    }
    let omega1_s = t0.elapsed().as_secs_f64() / REPS as f64 * m.depth as f64;

    // Ω₂ slope: gather half the columns of a [hs, ffl] matrix
    let w = Tensor::zeros(&[m.hs, m.ffl]);
    let idx: Vec<u32> = (0..(m.ffl / 2).max(1) as u32).collect();
    let t0 = std::time::Instant::now();
    for _ in 0..REPS {
        let g = w.gather_cols(&idx);
        std::hint::black_box(&g);
    }
    let per_gather = t0.elapsed().as_secs_f64() / REPS as f64;
    let omega2_per_col = per_gather / idx.len() as f64 * 2.0 * m.depth as f64;

    // Φ₁ from the α-β model, Φ₂ from the measured FFN executable time
    let (phi1_base_s, phi1_per_col) = phi1_fits(m, net);
    let phi2_per_col = mlp_fwd_bwd_secs / m.ffl as f64 * m.depth as f64;

    CostFns { omega1_s, omega2_per_col, phi1_base_s, phi1_per_col, phi2_per_col }
}

/// Φ₁ affine fit via two evaluation points of the analytic comm cost:
/// per migrated column and iteration, a tree broadcast of its 2·hs
/// weight values out plus a flat gather of the compact gradients back,
/// per layer.  Shared by the measured and deterministic pretests.
fn phi1_fits(m: &ModelInfo, net: &CostModel) -> (f64, f64) {
    let phi1_at = |cols: f64| -> f64 {
        if cols <= 0.0 {
            return 0.0;
        }
        let bytes = (2.0 * m.hs as f64 * cols * 4.0) as usize;
        let bcast = net.tree_rounds(m.e, bytes);
        let back = net.p2p(bytes);
        (bcast + back) * m.depth as f64
    };
    (phi1_at(1.0), (phi1_at(101.0) - phi1_at(1.0)) / 100.0)
}

/// Deterministic pretest for `--time-model modeled` runs (DESIGN.md
/// §12): the Ω fits come from byte-count formulas over the same shapes
/// the measured pretest touches — a [hs, ffl/2] submatrix allocation
/// (Ω₁) and per-column gathers of 2·hs weight values (Ω₂) at the
/// modeled alloc/copy bandwidths — instead of wall measurements, so
/// mid-run replans are bitwise reproducible across runs and thread
/// counts.  Φ₁ uses the α-β net model exactly like [`pretest`]; Φ₂
/// takes the *modeled* full-width FFN fwd+bwd seconds.
pub fn pretest_det(m: &ModelInfo, net: &CostModel, mlp_fwd_bwd_secs: f64) -> CostFns {
    use crate::contention::timemodel::{ALLOC_BYTES_PER_S, MEM_BYTES_PER_S};
    let omega1_s =
        (m.hs * (m.ffl / 2).max(1) * 4) as f64 / ALLOC_BYTES_PER_S * m.depth as f64;
    let omega2_per_col = (m.hs * 4) as f64 / MEM_BYTES_PER_S * 2.0 * m.depth as f64;
    let (phi1_base_s, phi1_per_col) = phi1_fits(m, net);
    CostFns {
        omega1_s,
        omega2_per_col,
        phi1_base_s,
        phi1_per_col,
        phi2_per_col: mlp_fwd_bwd_secs / m.ffl as f64 * m.depth as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_plain_matches_formula() {
        let mut opt = Sgd::new(0.1, 0.0);
        let mut p = Tensor::full(&[4], 1.0);
        let g = Tensor::full(&[4], 0.5);
        opt.update("a", &mut p, &g);
        assert!(p.allclose(&Tensor::full(&[4], 0.95), 1e-7));
        assert_eq!(opt.buffer_count(), 0); // no buffers without momentum
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut opt = Sgd::new(1.0, 0.5);
        let mut p = Tensor::full(&[1], 0.0);
        let g = Tensor::full(&[1], 1.0);
        opt.update("a", &mut p, &g); // v=1, p=-1
        assert!((p.data[0] + 1.0).abs() < 1e-7);
        opt.update("a", &mut p, &g); // v=1.5, p=-2.5
        assert!((p.data[0] + 2.5).abs() < 1e-6);
        assert_eq!(opt.buffer_count(), 1);
    }

    #[test]
    fn sgd_buffers_keyed_independently() {
        let mut opt = Sgd::new(1.0, 0.9);
        let mut p1 = Tensor::full(&[1], 0.0);
        let mut p2 = Tensor::full(&[1], 0.0);
        let g = Tensor::full(&[1], 1.0);
        opt.update("x", &mut p1, &g);
        opt.update("y", &mut p2, &g);
        assert_eq!(opt.buffer_count(), 2);
        assert_eq!(p1.data[0], p2.data[0]);
    }

    #[test]
    fn pretest_produces_positive_costs() {
        let m = ModelInfo {
            name: "t".into(), hs: 32, depth: 2, heads: 4, e: 4, bs: 2,
            classes: 10, seq: 17, seq0: 16, pd: 48, hsl: 8, hl: 1, hd: 8,
            ffl: 32, params_total: 0, params_per_worker: 0,
            degrees: crate::runtime::manifest::Degrees::uniform(4),
        };
        let c = pretest(&m, &CostModel::default(), 0.01);
        assert!(c.omega1_s >= 0.0);
        assert!(c.omega2_per_col > 0.0);
        assert!(c.phi1_per_col > 0.0);
        assert!(c.phi2_per_col > 0.0);
        // Φ₁ monotone
        assert!(c.phi1(10.0) < c.phi1(100.0));
    }

    #[test]
    fn pretest_det_is_deterministic_and_positive() {
        let m = ModelInfo {
            name: "t".into(), hs: 32, depth: 2, heads: 4, e: 4, bs: 2,
            classes: 10, seq: 17, seq0: 16, pd: 48, hsl: 8, hl: 1, hd: 8,
            ffl: 32, params_total: 0, params_per_worker: 0,
            degrees: crate::runtime::manifest::Degrees::uniform(4),
        };
        let a = pretest_det(&m, &CostModel::default(), 0.01);
        let b = pretest_det(&m, &CostModel::default(), 0.01);
        // bitwise equality — no wall measurements anywhere
        assert_eq!(a.omega1_s, b.omega1_s);
        assert_eq!(a.omega2_per_col, b.omega2_per_col);
        assert_eq!(a.phi1_base_s, b.phi1_base_s);
        assert_eq!(a.phi1_per_col, b.phi1_per_col);
        assert_eq!(a.phi2_per_col, b.phi2_per_col);
        assert!(a.omega1_s > 0.0 && a.omega2_per_col > 0.0 && a.phi2_per_col > 0.0);
        // Φ fits agree with the measured pretest (shared derivation)
        let c = pretest(&m, &CostModel::default(), 0.01);
        assert_eq!(a.phi1_base_s, c.phi1_base_s);
        assert_eq!(a.phi1_per_col, c.phi1_per_col);
        assert_eq!(a.phi2_per_col, c.phi2_per_col);
    }
}
