//! The lock-step tensor-parallel training engine (DESIGN.md §6.5).
//!
//! One iteration walks the classic 1D-TP dataflow: replicated embed →
//! per-block [attention branch → all-reduce → residual → FFN branch →
//! all-reduce → residual] → replicated head (loss + dx) → mirrored
//! backward with per-branch dx/LN-grad all-reduces → imputation → SGD.
//! Every backend call (native kernels by default, PJRT behind `--features
//! pjrt`) is timed for real; block-GEMM charges are multiplied by the
//! rank's skewness χ (the paper's sleep injection); collectives charge
//! the α-β model; RT = Σ_iters max-rank sim time.
//!
//! # Parallel rank execution
//!
//! Between collective boundaries the E simulated ranks are independent, so
//! their branch executables (and migration receiver slices) run
//! concurrently on a scoped thread pool ([`RankPool`], `--threads`).  The
//! engine keeps the serial semantics exactly: workers only *compute*;
//! every SimClock charge, `M_i` accumulation, comm-stat update, and
//! partial-sum merge happens afterwards on the coordinator thread in rank
//! order, and [`Comm::all_reduce`] reduces over a fixed binary tree — so
//! for a fixed balancing plan a `--threads 1` and a `--threads N` run
//! produce bitwise-identical losses
//! (pinned by `tests/parallel_determinism.rs`).  Real wall-clock drops
//! toward `max_i(rank i work)` per phase while the *simulated* clocks keep
//! the paper's lock-step accounting.
//!
//! Balancing hooks: the [`Balancer`] contributes per-rank [`WorkerAction`]s
//! each iteration — pruned executables + keep sets for ZERO-resizing,
//! migration plans whose receiver slices run here with reduce-merging.
//!
//! # Dynamic contention & replanning (DESIGN.md §12)
//!
//! χ is *iteration*-granular: a [`ContentionTrace`] realized once on the
//! coordinator (from `--scenario`/`--chi`/`--chis`) feeds the
//! [`Injector`] one snapshot per iteration.  `--replan` picks when the
//! plan is recomputed: every iteration (legacy), at epoch boundaries
//! (static baseline), or **online** — boundaries plus mid-epoch replans
//! triggered by the EWMA [`DriftDetector`] watching T_i, each charged
//! Ω₁ to the SimClock and preceded by a re-entrant pretest refit.
//! `--time-model modeled` swaps measured charges for deterministic
//! FLOP-model seconds, making whole dynamic runs (replans included)
//! bitwise thread-count-invariant and sweeps reproducible.
//!
//! # Checkpoint / elastic resume (DESIGN.md §13)
//!
//! Every completed iteration is a snapshot point: `--ckpt-dir` +
//! `--ckpt-every` write atomic `.flexckpt` files capturing the *whole*
//! training state — model shards, optimizer moments, data/RNG cursors,
//! monitor/controller statistics, the cached balancing plan, SimClock
//! and comm-stat accumulators, and the run report so far.  A same-`E`
//! [`Trainer::resume_from`] continues **bitwise identically** to the
//! uninterrupted run (pinned by `tests/checkpoint_resume.rs`); resuming
//! under a different `--e` re-shards the saved state exactly
//! (`checkpoint::elastic`) and re-runs the Eq. 2/3 allocation before the
//! first resumed iteration.

use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::balancer::{Balancer, WorkerAction};
use crate::cluster::Clocks;
use crate::collectives::{cost::CostModel, Comm};
use crate::config::{Imputation, MigPolicy, ReplanMode, RunCfg, Strategy, TimeModel};
use crate::contention::control::DriftDetector;
use crate::contention::{timemodel, ContentionTrace};
use crate::data::{Batch, SynthData};
use crate::metrics::{EpochMetrics, RunReport};
use crate::migration::Chunk;
use crate::model::{BlockGrads, ModelState};
use crate::resizing::lineage::{impute_cols, impute_rows, Lineage};
use crate::runtime::{Arg, Out, Runtime};
use crate::semi::CostFns;
use crate::straggler::{Injector, Monitor};
use crate::tensor::{linalg, Tensor, Workspace};
use crate::train::parallel::RankPool;
use crate::train::Sgd;

pub struct Trainer {
    pub cfg: RunCfg,
    pub rt: Runtime,
    pub state: ModelState,
    pub data: SynthData,
    pub comm: Comm,
    pub clocks: Clocks,
    pub monitor: Monitor,
    pub balancer: Balancer,
    pub opt: Sgd,
    pub report: RunReport,
    pub costs: CostFns,
    /// scoped thread pool running per-rank work between collectives
    pool: RankPool,
    /// per-rank scratch arenas: rank w's backend calls draw every
    /// intermediate buffer from `ws[w]`, and the coordinator feeds merged
    /// output buffers back — steady-state iterations reuse instead of
    /// allocating.  Mutex only because pool workers borrow slots through
    /// a shared slice; each slot is touched by one job at a time.
    ws: Vec<Mutex<Workspace>>,
    injector: Injector,
    /// realized per-iteration contention trace (DESIGN.md §12) — built
    /// once on the coordinator from `cfg.stragglers`; workers never
    /// observe or advance trace state
    trace: ContentionTrace,
    /// span recorder (DESIGN.md §17), shared with [`Comm`] so collectives
    /// log their wait/transfer split.  Only the coordinator thread ever
    /// locks it, always in rank-order replay loops, and it never touches
    /// a clock — tracing on/off is bitwise-invisible to the simulation
    /// (`tests/trace_determinism.rs`).  None unless `--trace`/`--timeline`.
    pub tracer: Option<std::sync::Arc<Mutex<crate::trace::Tracer>>>,
    /// EWMA drift detector driving `--replan online`
    pub controller: DriftDetector,
    /// plan cache for the epoch/online replan modes (checkpointed so a
    /// mid-epoch resume reuses the very plan the killed run was on)
    pub(crate) cached_actions: Option<Vec<WorkerAction>>,
    /// true while warmup_and_pretest's untimed iteration runs: the trace
    /// is not applied and plan/χ accounting is suppressed
    warming: bool,
    /// previous-iteration grads per (worker, block) — Same policy only
    pub(crate) prev_grads: Option<Vec<Vec<BlockGrads>>>,
    /// fixed-batch override (golden tests)
    pub forced_batch: Option<Batch>,
    /// forced per-worker actions (golden pruned-step test)
    pub forced_actions: Option<Vec<WorkerAction>>,
    pub(crate) global_iter: u64,
    // -- epoch-in-progress accumulators (checkpointed: a mid-epoch
    //    resume finishes the epoch with the interrupted run's partials)
    pub(crate) epoch_pruned_cols: u64,
    pub(crate) epoch_migrated_cols: u64,
    pub(crate) epoch_compute: Vec<f64>,
    pub(crate) epoch_replans: u64,
    pub(crate) epoch_chi_sum: f64,
    pub(crate) epoch_chi_max: f64,
    pub(crate) epoch_chi_iters: u64,
    pub(crate) epoch_loss_sum: f64,
    /// `CommStats::total_bytes` at the epoch boundary (per-epoch deltas)
    pub(crate) epoch_start_bytes: u64,
    /// accumulated real wall seconds of this epoch across kill/resume
    /// segments (the only non-bitwise epoch metric)
    pub(crate) epoch_wall_s: f64,
    /// true after a checkpoint restore: `run_to` skips warmup/pretest
    /// (the restored costs/statistics already include it)
    pub(crate) resumed: bool,
    last_replanned: bool,
    /// scenario worker-churn schedule (DESIGN.md §14), sorted by firing
    /// iteration; empty unless the scenario scripts churn and
    /// `cfg.train.churn` is on
    pub(crate) churn: Vec<crate::contention::ChurnEvent>,
    /// cursor into `churn`: how many events have fired (checkpointed
    /// implicitly — recomputed from the restored `global_iter`)
    pub(crate) churn_fired: usize,
    /// live worker count implied by the churn schedule.  May differ from
    /// the sharding degree `model().e` when no larger divisor of
    /// hs/heads fits (e.g. 3 live workers run sharded over 2).
    /// Checkpointed: a resumed run must count joins/leaves from the
    /// same baseline as the uninterrupted one.
    pub(crate) avail: usize,
    /// per-rank memory ledger (DESIGN.md §16).  A pure function of
    /// (cfg, current E, fired mem events) — [`Trainer::rebuild_ledger`]
    /// reconstructs it after every re-shard/restore, which is what keeps
    /// live OOM eviction bitwise equal to the resume oracle.
    pub ledger: crate::memory::MemLedger,
    /// modeled per-rank footprint for the current manifest
    pub(crate) footprint: crate::memory::FootprintModel,
    /// scenario memory events (DESIGN.md §16), sorted by firing
    /// iteration; they fire before the iteration at their cursor, like
    /// churn
    pub(crate) mem_events: Vec<crate::contention::MemEvent>,
    /// cursor into `mem_events` (recomputed from the restored
    /// `global_iter`, like `churn_fired`)
    pub(crate) mem_fired: usize,
    // -- memory epoch accumulators (checkpointed like the others)
    pub(crate) epoch_mem_hwm: u64,
    pub(crate) epoch_headroom_min: u64,
    pub(crate) epoch_recompute_iters: u64,
}

impl Trainer {
    pub fn new(cfg: RunCfg) -> Result<Trainer> {
        let degreeful = cfg.degree_overrides.any() || cfg.degrees_auto;
        let rt = match (cfg.e_override, degreeful) {
            (None, false) => Runtime::open(&cfg.model_dir(), &cfg.model, cfg.backend)
                .with_context(|| {
                    format!("opening {} backend for '{}'", cfg.backend.name(), cfg.model)
                })?,
            (e_ov, _) => {
                anyhow::ensure!(
                    cfg.backend == crate::config::BackendKind::Native,
                    "--e / --e-* / --degrees (elastic geometry overrides) require \
                     the native backend"
                );
                let e = match e_ov {
                    Some(e) => e,
                    None => crate::runtime::presets::preset(&cfg.model)?.e,
                };
                let man = resolved_manifest(&cfg, e)
                    .with_context(|| format!("sharding '{}' over {e} workers", cfg.model))?;
                Runtime::native_with_manifest(man)
            }
        };
        let m = rt.manifest.model.clone();
        let state = ModelState::init(&m, cfg.train.seed);
        let data = SynthData::new(&m, cfg.train.seed);
        let comm = match cfg.train.transport {
            crate::config::TransportKind::InProc => Comm::new(CostModel::from_net(cfg.net)),
            crate::config::TransportKind::Tcp => {
                anyhow::ensure!(
                    cfg.backend == crate::config::BackendKind::Native,
                    "--transport tcp (multi-process ranks) requires the native backend"
                );
                // lazy transport: rank processes spawn at the first
                // collective, so building (or restoring) a trainer never
                // forks
                Comm::with_transport(
                    CostModel::from_net(cfg.net),
                    Box::new(crate::collectives::transport::LocalTcp::new(
                        cfg.train.transport_timeout_ms,
                        cfg.train.rank_exe.clone(),
                    )),
                )
            }
        };
        let clocks = Clocks::new(m.e);
        let monitor = Monitor::new(m.e);
        let balancer = Balancer::new(cfg.balancer.clone(), &rt.manifest, cfg.train.seed);
        let opt = Sgd::new(cfg.train.lr, cfg.train.momentum);
        let label = format!("{}/{}", cfg.model, cfg.balancer.strategy.name());
        let costs = CostFns {
            omega1_s: 1e-6,
            omega2_per_col: 1e-7,
            phi1_base_s: 1e-6,
            phi1_per_col: 1e-7,
            phi2_per_col: 1e-6,
        };
        let prev_grads = if cfg.balancer.imputation == Imputation::Same {
            Some(
                (0..m.e)
                    .map(|_| (0..m.depth).map(|_| crate::model::zero_block_grads(&m)).collect())
                    .collect(),
            )
        } else {
            None
        };
        let pool = RankPool::new(cfg.train.threads);
        let ws = (0..m.e).map(|_| Mutex::new(Workspace::new())).collect();
        // realize the whole run's contention trace up front, on the
        // coordinator: queries are pure slice reads afterwards.  A
        // scenario naming a rank outside the worker group is an error,
        // not a silently-calm trace.
        if let crate::config::StragglerPlan::Scenario(spec) = &cfg.stragglers {
            spec.validate_ranks(m.e)
                .with_context(|| format!("scenario invalid for model '{}'", cfg.model))?;
        }
        let trace = ContentionTrace::from_plan(
            &cfg.stragglers,
            m.e,
            cfg.train.epochs,
            cfg.train.iters_per_epoch,
        );
        let controller = DriftDetector::new(cfg.control);
        let mut injector = Injector::homogeneous(m.e);
        injector.emulate_wall = cfg.train.emulate_wall;
        let churn = match &cfg.stragglers {
            crate::config::StragglerPlan::Scenario(spec) if cfg.train.churn => {
                spec.churn_sorted()
            }
            _ => Vec::new(),
        };
        let mem_events = match &cfg.stragglers {
            crate::config::StragglerPlan::Scenario(spec) => spec.mem_sorted(),
            _ => Vec::new(),
        };
        if !churn.is_empty()
            || (cfg.train.churn
                && mem_events.iter().any(|ev| ev.kind == crate::contention::MemKind::Oom))
        {
            anyhow::ensure!(
                cfg.backend == crate::config::BackendKind::Native,
                "worker-churn scenarios (live re-sharding) require the native backend"
            );
        }
        let avail = m.e;
        let footprint = crate::memory::FootprintModel::new(&m);
        let cap = cfg.train.mem_cap.unwrap_or_else(|| crate::memory::default_cap(&m));
        let mut ledger = crate::memory::MemLedger::new(m.e, cap, &cfg.train.mem_caps);
        for r in 0..m.e {
            ledger.charge(r, footprint.static_bytes());
        }
        let tracer = if cfg.train.trace || cfg.train.timeline {
            Some(std::sync::Arc::new(Mutex::new(crate::trace::Tracer::new(
                m.e,
                cfg.train.trace_ring,
                cfg.train.trace,
                cfg.train.timeline,
            ))))
        } else {
            None
        };
        let mut comm = comm;
        comm.tracer = tracer.clone();
        Ok(Trainer {
            pool,
            ws,
            injector,
            trace,
            tracer,
            controller,
            cached_actions: None,
            warming: false,
            cfg,
            rt,
            state,
            data,
            comm,
            clocks,
            monitor,
            balancer,
            opt,
            report: RunReport::new(&label),
            costs,
            prev_grads,
            forced_batch: None,
            forced_actions: None,
            global_iter: 0,
            epoch_pruned_cols: 0,
            epoch_migrated_cols: 0,
            epoch_compute: Vec::new(),
            epoch_replans: 0,
            epoch_chi_sum: 0.0,
            epoch_chi_max: 0.0,
            epoch_chi_iters: 0,
            epoch_loss_sum: 0.0,
            epoch_start_bytes: 0,
            epoch_wall_s: 0.0,
            resumed: false,
            last_replanned: false,
            churn,
            churn_fired: 0,
            avail,
            ledger,
            footprint,
            mem_events,
            mem_fired: 0,
            epoch_mem_hwm: 0,
            epoch_headroom_min: u64::MAX,
            epoch_recompute_iters: 0,
        })
    }

    /// Build a trainer and restore it from a checkpoint — a `.flexckpt`
    /// file or a checkpoint directory (newest complete snapshot wins).
    ///
    /// With the same config and worker count the resumed run continues
    /// **bitwise identically** to the uninterrupted one (losses, eval
    /// metrics, `CommStats`); with a different `cfg.e_override` the saved
    /// state is elastically re-sharded (DESIGN.md §13) and continuation
    /// is loss-equivalent rather than bitwise.
    pub fn resume_from(cfg: RunCfg, from: &std::path::Path) -> Result<Trainer> {
        let path = if from.is_dir() {
            crate::checkpoint::latest_in_dir(from).with_context(|| {
                format!("no complete ckpt-*.flexckpt snapshot in {}", from.display())
            })?
        } else {
            from.to_path_buf()
        };
        let snap = crate::checkpoint::Snapshot::load(&path)
            .with_context(|| format!("loading checkpoint {}", path.display()))?;
        let mut t = Trainer::new(cfg)?;
        crate::checkpoint::restore_trainer(&mut t, &snap)
            .with_context(|| format!("restoring {}", path.display()))?;
        Ok(t)
    }

    /// The global-iteration cursor: iterations completed so far
    /// (`epoch · iters_per_epoch + iter`); also the data-stream position.
    pub fn giter(&self) -> u64 {
        self.global_iter
    }

    /// Has the configured schedule (epochs × iters) fully run?
    pub fn is_complete(&self) -> bool {
        self.global_iter
            >= (self.cfg.train.epochs * self.cfg.train.iters_per_epoch) as u64
    }

    pub fn model(&self) -> &crate::runtime::manifest::ModelInfo {
        &self.rt.manifest.model
    }

    /// Resolved rank-execution thread count (`--threads`, 0 = all cores).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Backend call with this trainer's intra-op GEMM fan-out — used for
    /// the replicated single-call roles (embed/head) executed on the
    /// coordinator thread.  Scoped per call (not a process global) so
    /// concurrently live trainers with different `--threads` settings
    /// cannot stomp each other's width.  Scratch comes from the
    /// coordinator thread's shared workspace (`Runtime::call`).
    fn call_wide(&self, name: &str, args: &[Arg]) -> Result<(Vec<Out>, f64)> {
        linalg::with_gemm_threads(self.pool.threads(), || self.rt.call(name, args))
    }

    /// Give a merged per-rank output buffer back to rank `w`'s workspace
    /// — the other half of the zero-alloc loop: rank jobs `take` their
    /// buffers, the coordinator returns them once folded.
    fn recycle_rank(&self, w: usize, t: Tensor) {
        self.ws[w].lock().expect("workspace lock poisoned").give(t.data);
    }

    /// Fresh per-(worker, block) gradient sinks drawn from each rank's
    /// workspace (shapes of [`crate::model::zero_block_grads`]).
    ///
    /// Every field is overwritten in full before its first read — the
    /// weight grads are `mem::replace`d with backend outputs and the LN
    /// grads `copy_from_slice`d from the reduced partials in
    /// `attn_bwd`/`mlp_bwd`, which run for every block before
    /// `impute_and_step` touches anything — so the buffers come from
    /// `take_unfilled` and skip ~1.6 MB of pure memset per iteration.
    fn zeroed_block_grads(&self) -> Vec<Vec<BlockGrads>> {
        let m = &self.rt.manifest.model;
        (0..m.e)
            .map(|w| {
                let mut ws = self.ws[w].lock().expect("workspace lock poisoned");
                (0..m.depth)
                    .map(|_| crate::model::BlockShard {
                        ln1_g: Tensor::from_vec(&[m.hs], ws.take_unfilled(m.hs)),
                        ln1_b: Tensor::from_vec(&[m.hs], ws.take_unfilled(m.hs)),
                        wqkv: Tensor::from_vec(
                            &[m.hs, 3 * m.hsl],
                            ws.take_unfilled(m.hs * 3 * m.hsl),
                        ),
                        wo: Tensor::from_vec(&[m.hsl, m.hs], ws.take_unfilled(m.hsl * m.hs)),
                        ln2_g: Tensor::from_vec(&[m.hs], ws.take_unfilled(m.hs)),
                        ln2_b: Tensor::from_vec(&[m.hs], ws.take_unfilled(m.hs)),
                        w1: Tensor::from_vec(&[m.hs, m.ffl], ws.take_unfilled(m.hs * m.ffl)),
                        w2: Tensor::from_vec(&[m.ffl, m.hs], ws.take_unfilled(m.ffl * m.hs)),
                    })
                    .collect()
            })
            .collect()
    }

    /// Full run: warmup/pretest (fresh runs only), then epochs of
    /// train + eval, starting wherever the cursor points.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_to(None)
    }

    /// [`Trainer::run`], stopping after global iteration `stop_after`
    /// completes (post-iteration point — a simulated preemption: the
    /// state left behind is exactly what [`Trainer::save_checkpoint`]
    /// snapshots and what a resumed trainer continues from).  `None`
    /// runs the whole schedule.
    pub fn run_to(&mut self, stop_after: Option<u64>) -> Result<RunReport> {
        // a cursor already at/past the stop point trains nothing — the
        // contract is "stop once iteration N has completed", and it has
        if let Some(stop) = stop_after {
            if self.global_iter >= stop {
                return Ok(self.report.clone());
            }
        }
        if !self.resumed && self.global_iter == 0 && self.report.epochs.is_empty() {
            self.warmup_and_pretest()?;
        }
        let ipe = self.cfg.train.iters_per_epoch.max(1);
        let start_epoch = (self.global_iter as usize) / ipe;
        for epoch in start_epoch..self.cfg.train.epochs {
            if self.run_epoch_to(epoch, stop_after)? {
                break;
            }
        }
        Ok(self.report.clone())
    }

    pub fn run_epoch(&mut self, epoch: usize) -> Result<()> {
        self.run_epoch_to(epoch, None).map(|_| ())
    }

    /// Run (the rest of) one epoch.  A fresh epoch (cursor at the
    /// boundary) resets the per-epoch accumulators; a resumed mid-epoch
    /// cursor continues on the restored partials — that is what makes a
    /// same-`E` resume bitwise-identical to the uninterrupted run.
    /// Returns true when `stop_after` fired inside this epoch.
    fn run_epoch_to(&mut self, epoch: usize, stop_after: Option<u64>) -> Result<bool> {
        let e = self.model().e;
        let ipe = self.cfg.train.iters_per_epoch;
        let base = (epoch * ipe) as u64;
        anyhow::ensure!(
            self.global_iter >= base && (self.global_iter - base) < ipe.max(1) as u64,
            "cursor (global_iter {}) is outside epoch {epoch} [{base}, {})",
            self.global_iter,
            base + ipe as u64,
        );
        let start_iter = (self.global_iter - base) as usize;
        if start_iter == 0 {
            // the tracer folds the finished epoch's frontier into its
            // cumulative base *before* the reset, so exported span
            // timelines stay monotone across epochs
            if let Some(tr) = &self.tracer {
                tr.lock().expect("tracer lock").epoch_rollover(self.clocks.max());
            }
            // χ applies per *iteration* from the realized trace inside
            // train_iter (the injector snapshots one row per iteration)
            self.clocks.reset();
            self.epoch_pruned_cols = 0;
            self.epoch_migrated_cols = 0;
            self.epoch_compute = vec![0.0; e];
            self.epoch_replans = 0;
            self.epoch_chi_sum = 0.0;
            self.epoch_chi_max = 0.0;
            self.epoch_chi_iters = 0;
            self.epoch_loss_sum = 0.0;
            self.epoch_wall_s = 0.0;
            self.epoch_start_bytes = self.comm.stats.total_bytes();
            self.epoch_mem_hwm = 0;
            self.epoch_headroom_min = u64::MAX;
            self.epoch_recompute_iters = 0;
        }
        let mut wall0 = std::time::Instant::now();
        // with OS-process ranks a peer can really die mid-iteration; an
        // in-memory pre-iteration snapshot (the exact bytes
        // save_checkpoint would write at this cut) is the recovery point
        let recoverable = self.cfg.train.transport == crate::config::TransportKind::Tcp;
        for it in start_iter..ipe {
            // scheduled worker churn fires *before* the iteration at its
            // firing cursor — exactly the cut a kill-at-`at` checkpoint
            // makes, so live transitions and the kill/resume oracle see
            // identical state (tests/elastic_live.rs)
            self.apply_churn_transitions()?;
            // memory events fire at the same cut, after churn: a squeeze
            // that leaves a rank's resident set over its shrunken cap is
            // a hard OOM and routes through the same eviction math
            self.apply_mem_transitions()?;
            let loss = loop {
                let snap = if recoverable {
                    Some(crate::checkpoint::save_trainer(self))
                } else {
                    None
                };
                match self.train_iter() {
                    Ok(loss) => break loss,
                    Err(err) => {
                        // only a typed PeerDied is survivable — and only
                        // when a snapshot exists to rebuild from.
                        // Timeouts, frame corruption, and everything
                        // else still propagate.
                        let (Some(snap), Some(dead)) = (snap, peer_died_rank(&err)) else {
                            return Err(err);
                        };
                        self.recover_from_peer_death(&snap, dead).with_context(|| {
                            format!(
                                "recovering from dead rank {dead} at iteration {}",
                                self.global_iter
                            )
                        })?;
                        // retry the same iteration on the survivors;
                        // each attempt burns one worker, so avail hits
                        // the typed NoViableWorkerCount floor before any
                        // unbounded retry loop could form
                    }
                }
            };
            self.epoch_loss_sum += loss as f64;
            self.report.loss_curve.push(loss);
            if it + 1 == ipe {
                self.finalize_epoch(epoch, &mut wall0)?;
            }
            self.maybe_checkpoint(&mut wall0)?;
            if let Some(stop) = stop_after {
                if self.global_iter >= stop {
                    self.epoch_wall_s += take_wall(&mut wall0);
                    return Ok(true);
                }
            }
        }
        Ok(false)
    }

    /// Epoch close-out: eval, balancer statistics refresh, metrics push.
    /// Runs right after the epoch's last iteration, *before* any
    /// checkpoint at that boundary — so a boundary snapshot already
    /// contains the finalized epoch and a resume starts the next one.
    fn finalize_epoch(&mut self, epoch: usize, wall0: &mut std::time::Instant) -> Result<()> {
        let e = self.model().e;
        let (eval_loss, acc) = self.eval()?;
        self.balancer.epoch_end(&self.state);
        self.epoch_wall_s += take_wall(wall0);
        let chi_cells = self.epoch_chi_iters.saturating_mul(e as u64);
        self.report.epochs.push(EpochMetrics {
            epoch,
            // clocks reset at the epoch boundary, so the frontier IS the
            // epoch's simulated runtime (Σ-of-deltas telescopes to it)
            rt_sim_s: self.clocks.max(),
            rt_wall_s: self.epoch_wall_s,
            train_loss: self.epoch_loss_sum / self.cfg.train.iters_per_epoch as f64,
            eval_loss,
            acc,
            comm_bytes: self.comm.stats.total_bytes() - self.epoch_start_bytes,
            pruned_cols: self.epoch_pruned_cols,
            migrated_cols: self.epoch_migrated_cols,
            rank_compute_s: self.epoch_compute.clone(),
            replans: self.epoch_replans,
            chi_mean: if chi_cells > 0 {
                self.epoch_chi_sum / chi_cells as f64
            } else {
                1.0
            },
            chi_max: self.epoch_chi_max,
            mem_hwm_bytes: self.epoch_mem_hwm,
            mem_headroom_min_bytes: if self.epoch_headroom_min == u64::MAX {
                0
            } else {
                self.epoch_headroom_min
            },
            recompute_iters: self.epoch_recompute_iters,
        });
        Ok(())
    }

    /// Periodic snapshot: every `--ckpt-every` completed iterations,
    /// written atomically into `--ckpt-dir` as `ckpt-<giter>.flexckpt`.
    fn maybe_checkpoint(&mut self, wall0: &mut std::time::Instant) -> Result<()> {
        let every = self.cfg.train.ckpt_every as u64;
        let Some(dir) = self.cfg.train.ckpt_dir.clone() else { return Ok(()) };
        if every == 0 || self.global_iter == 0 || self.global_iter % every != 0 {
            return Ok(());
        }
        // wall time up to the snapshot belongs to this run segment; the
        // resumed segment adds its own on top of the serialized value
        self.epoch_wall_s += take_wall(wall0);
        let path = dir.join(crate::checkpoint::ckpt_filename(self.global_iter));
        self.save_checkpoint(&path)
    }

    /// Snapshot the complete trainer state to `path` (atomic write —
    /// a crash leaves no torn checkpoint).  See `checkpoint` module docs
    /// for exactly what is captured.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let snap = crate::checkpoint::save_trainer(self);
        snap.save_atomic(path)
            .with_context(|| format!("writing checkpoint {}", path.display()))?;
        self.trace_event(crate::trace::Kind::Checkpoint, "checkpoint", 0.0, 0);
        Ok(())
    }

    /// One untimed baseline iteration: compiles the hot executables and
    /// measures the FFN time the pretest needs. Model state is restored.
    /// The contention trace is *not* applied during warmup (homogeneous
    /// charges), and any plan cached while warming is dropped.
    pub fn warmup_and_pretest(&mut self) -> Result<()> {
        let saved = self.state.clone();
        let saved_clocks = self.clocks.clone();
        self.warming = true;
        // the warmup iteration is untimed and later undone — parking the
        // tracer keeps its event stream identical to a resumed run's
        if let Some(tr) = &self.tracer {
            tr.lock().expect("tracer lock").set_active(false);
        }
        let warm = self.train_iter();
        if let Some(tr) = &self.tracer {
            tr.lock().expect("tracer lock").set_active(true);
        }
        self.warming = false;
        warm?;
        self.state = saved;
        self.clocks = saved_clocks;
        self.report.loss_curve.clear();
        self.global_iter = 0;
        self.cached_actions = None;
        // re-seed the drift detector with the homogeneous warmup stats so
        // the first real iteration is a baseline, not a phantom drift
        self.controller = DriftDetector::new(self.cfg.control);
        self.controller.observe(&self.monitor.t_iter);
        self.costs = self.fresh_cost_fit();
        Ok(())
    }

    /// One pretest cost fit from the current timing profile (measured
    /// mode) or the deterministic FLOP model — shared by warmup, the
    /// online controller's refits, and elastic resume.
    pub(crate) fn fresh_cost_fit(&self) -> CostFns {
        let m = self.rt.manifest.model.clone();
        match self.cfg.train.time_model {
            TimeModel::Measured => {
                let prof = self.rt.timing_profile();
                let mlp_secs: f64 = prof
                    .iter()
                    .filter(|(n, _, _)| n.starts_with("mlp_fwd") || n.starts_with("mlp_bwd"))
                    .map(|(_, calls, secs)| secs / (*calls).max(1) as f64)
                    .sum();
                crate::train::pretest(&m, &self.comm.cost, mlp_secs)
            }
            TimeModel::Modeled => crate::train::pretest_det(
                &m,
                &self.comm.cost,
                timemodel::mlp_s(&m, m.hs, m.ffl, false) + timemodel::mlp_s(&m, m.hs, m.ffl, true),
            ),
        }
    }

    // -----------------------------------------------------------------
    // Live elastic re-parallelization (DESIGN.md §14)
    // -----------------------------------------------------------------

    /// Fire every churn event whose iteration has been reached, then —
    /// if the implied sharding degree changed — re-shard in-process.
    /// Joins and leaves/fails only move the live worker *count*; the
    /// sharding degree is the largest divisor of hs/heads it admits
    /// (nearest-valid-divisor degradation: 3 live workers run sharded
    /// over 2).  Zero live workers is a typed error, never a panic.
    fn apply_churn_transitions(&mut self) -> Result<()> {
        if self.churn_fired >= self.churn.len() {
            return Ok(());
        }
        let mut fired = false;
        while self.churn_fired < self.churn.len() {
            let ev = self.churn[self.churn_fired];
            if (ev.at as u64) > self.global_iter {
                break;
            }
            let kind_s = match ev.kind {
                crate::contention::ChurnKind::Join => {
                    self.avail += 1;
                    "join"
                }
                crate::contention::ChurnKind::Leave => {
                    self.avail = self.avail.saturating_sub(1);
                    "leave"
                }
                crate::contention::ChurnKind::Fail => {
                    self.avail = self.avail.saturating_sub(1);
                    "fail"
                }
            };
            self.trace_event(
                crate::trace::Kind::Churn,
                &format!("{kind_s}:r{}", ev.rank),
                0.0,
                0,
            );
            self.churn_fired += 1;
            fired = true;
        }
        if !fired {
            return Ok(());
        }
        let m = self.rt.manifest.model.clone();
        if self.avail == 0 {
            return Err(anyhow::Error::from(
                crate::contention::ScenarioError::NoViableWorkerCount {
                    avail: 0,
                    hs: m.hs,
                    heads: m.heads,
                },
            )
            .context(format!("worker churn at iteration {}", self.global_iter)));
        }
        // the group width only needs to divide hs — attention, the one
        // component that also slices whole heads, clamps its own degree
        // inside the geometry resolution (DESIGN.md §18)
        let target = (1..=self.avail).rev().find(|d| m.hs % d == 0).unwrap_or(1);
        // a same-degree outcome (e.g. a join with no larger divisor to
        // grow into, or the kill/resume oracle already running at E') is
        // a pure cursor advance — no transient may be touched, or a
        // same-E resume would stop being bitwise
        if target != m.e {
            self.transition_to(target).with_context(|| {
                format!(
                    "live transition {}→{target} at iteration {}",
                    m.e, self.global_iter
                )
            })?;
        }
        Ok(())
    }

    /// Rebuild the memory ledger from scratch: capacities from cfg for
    /// the *current* sharding degree, squeezes re-applied from already
    /// fired events (ranks outside the shrunken group are dropped),
    /// statics charged.  Because the result depends only on
    /// (cfg, current E, fired events), a live OOM eviction and the
    /// kill/checkpoint/`--resume --e E'` oracle reconstruct the exact
    /// same ledger — the memory half of the bitwise-transition invariant.
    pub(crate) fn rebuild_ledger(&mut self) {
        let m = self.rt.manifest.model.clone();
        let cap = self.cfg.train.mem_cap.unwrap_or_else(|| crate::memory::default_cap(&m));
        let mut ledger = crate::memory::MemLedger::new(m.e, cap, &self.cfg.train.mem_caps);
        for ev in &self.mem_events[..self.mem_fired.min(self.mem_events.len())] {
            if ev.rank < m.e {
                if let crate::contention::MemKind::Squeeze { frac } = ev.kind {
                    ledger.set_squeeze(ev.rank, frac);
                }
            }
        }
        let footprint = crate::memory::FootprintModel::new(&m);
        for r in 0..m.e {
            ledger.charge(r, footprint.static_bytes());
        }
        self.footprint = footprint;
        self.ledger = ledger;
    }

    /// Fire scheduled memory events whose cursor has arrived, then
    /// enforce the hard invariant that every rank's *resident* set
    /// (weights + moments + gradients) fits its effective capacity.
    /// A rank that no longer fits is a hard OOM: `handle_oom` routes it
    /// through the churn eviction math (or a typed error when churn
    /// recovery is off).  The loop re-checks after every eviction
    /// because shrinking E grows each survivor's shard — a cascade
    /// terminates at the typed `NoViableWorkerCount` floor.
    fn apply_mem_transitions(&mut self) -> Result<()> {
        while self.mem_fired < self.mem_events.len() {
            let ev = self.mem_events[self.mem_fired].clone();
            if (ev.at as u64) > self.global_iter {
                break;
            }
            self.mem_fired += 1;
            let e = self.rt.manifest.model.e;
            match ev.kind {
                crate::contention::MemKind::Squeeze { frac } => {
                    // ranks renumber on re-shard; a squeeze naming a rank
                    // outside the current group has nothing to squeeze
                    if ev.rank < e {
                        self.ledger.set_squeeze(ev.rank, frac);
                        self.trace_event(
                            crate::trace::Kind::Mem,
                            &format!("squeeze:r{}", ev.rank),
                            0.0,
                            self.ledger.effective_cap(ev.rank),
                        );
                        // trim the real arena to the shrunken budget too —
                        // retained capacity is observability, not math, so
                        // this cannot perturb determinism
                        let budget = self.footprint.workspace_budget() as usize;
                        if let Ok(mut ws) = self.ws[ev.rank].lock() {
                            ws.shrink_to(budget);
                        }
                    }
                }
                // a forced OOM is rank-descriptive like `fail:` — the
                // group re-shards, survivor identity is not tracked
                crate::contention::MemKind::Oom => self.handle_oom(ev.rank)?,
            }
        }
        loop {
            let e = self.rt.manifest.model.e;
            let Some(r) =
                (0..e).find(|&r| self.ledger.used(r) > self.ledger.effective_cap(r))
            else {
                break;
            };
            self.handle_oom(r)?;
        }
        Ok(())
    }

    /// Hard out-of-memory on `rank`.  Never a panic: with churn recovery
    /// on, the rank is evicted and the survivors re-shard through
    /// exactly the `fail:` math (`avail`−1 → nearest divisor →
    /// `transition_to`), so recovery is bitwise equal to the
    /// kill/checkpoint/`--resume --e E'` oracle; with it off, the typed
    /// `MemError::OutOfMemory` propagates to the caller (sweeps record
    /// it as an error row).
    fn handle_oom(&mut self, rank: usize) -> Result<()> {
        let (need, cap) = if rank < self.ledger.e() {
            (self.ledger.used(rank), self.ledger.effective_cap(rank))
        } else {
            (0, 0)
        };
        let oom = crate::memory::MemError::OutOfMemory {
            rank,
            need_bytes: need,
            cap_bytes: cap,
        };
        let ctx = format!("hard OOM on rank {rank} at iteration {}", self.global_iter);
        if !self.cfg.train.churn {
            return Err(anyhow::Error::from(oom).context(ctx));
        }
        self.trace_event(crate::trace::Kind::Mem, &format!("oom-evict:r{rank}"), 0.0, need);
        self.avail = self.avail.saturating_sub(1);
        let m = self.rt.manifest.model.clone();
        if self.avail == 0 {
            return Err(anyhow::Error::from(
                crate::contention::ScenarioError::NoViableWorkerCount {
                    avail: 0,
                    hs: m.hs,
                    heads: m.heads,
                },
            )
            .context(ctx));
        }
        let target = (1..=self.avail).rev().find(|d| m.hs % d == 0).unwrap_or(1);
        if target != m.e {
            self.transition_to(target).with_context(|| {
                format!(
                    "OOM eviction {}→{target} at iteration {}",
                    m.e, self.global_iter
                )
            })?;
        }
        Ok(())
    }

    /// In-process elastic re-shard onto `new_e` workers — no `.flexckpt`
    /// round-trip.  Field by field this reproduces exactly what
    /// `Trainer::new(--e new_e)` + the checkpoint elastic-restore path
    /// builds, which is what makes a live transition bitwise identical
    /// to the kill/checkpoint/resume oracle (tests/elastic_live.rs):
    ///
    /// * re-sharded (pure slicing): model shards, SGD momentum;
    /// * carried: comm cost model + stats, run report, epoch scalar
    ///   accumulators, the global-iteration/data cursor;
    /// * re-initialized at the new width: clocks (synced to the old
    ///   frontier — a re-shard is a barrier), monitor, drift detector,
    ///   balancer (trackers + RNG from seed), injector, workspaces,
    ///   realized trace, Same-imputation gradient history, per-rank
    ///   compute accumulator, plan cache, pretest cost fit.
    fn transition_to(&mut self, new_e: usize) -> Result<()> {
        let old_m = self.rt.manifest.model.clone();
        self.trace_event(
            crate::trace::Kind::Churn,
            &format!("transition:{}->{new_e}", old_m.e),
            0.0,
            0,
        );
        let man = resolved_manifest(&self.cfg, new_e)
            .with_context(|| format!("re-sharding '{}' over {new_e} workers", self.cfg.model))?;
        let rt = Runtime::native_with_manifest(man);
        let new_m = rt.manifest.model.clone();
        self.state = crate::checkpoint::elastic::reshard_state(&old_m, &new_m, &self.state);
        self.opt.bufs =
            crate::checkpoint::elastic::reshard_moments(&old_m, &new_m, &self.opt.bufs);
        self.rt = rt;
        self.data = SynthData::new(&new_m, self.cfg.train.seed);
        let frontier = self.clocks.max();
        self.clocks = Clocks::new(new_m.e);
        self.clocks.t.fill(frontier);
        self.monitor = Monitor::new(new_m.e);
        self.balancer =
            Balancer::new(self.cfg.balancer.clone(), &self.rt.manifest, self.cfg.train.seed);
        self.controller = DriftDetector::new(self.cfg.control);
        let mut injector = Injector::homogeneous(new_m.e);
        injector.emulate_wall = self.cfg.train.emulate_wall;
        self.injector = injector;
        self.ws = (0..new_m.e).map(|_| Mutex::new(Workspace::new())).collect();
        self.trace = ContentionTrace::from_plan(
            &self.cfg.stragglers,
            new_m.e,
            self.cfg.train.epochs,
            self.cfg.train.iters_per_epoch,
        );
        if self.prev_grads.is_some() {
            self.prev_grads = Some(
                (0..new_m.e)
                    .map(|_| {
                        (0..new_m.depth)
                            .map(|_| crate::model::zero_block_grads(&new_m))
                            .collect()
                    })
                    .collect(),
            );
        }
        self.epoch_compute = vec![0.0; new_m.e];
        self.cached_actions = None;
        self.costs = self.fresh_cost_fit();
        // ledger is a pure function of (cfg, new E, fired mem events) —
        // rebuilding here is what keeps it bitwise equal to the one the
        // resume oracle constructs; fresh arenas then start under budget
        self.rebuild_ledger();
        let ws_budget = self.footprint.workspace_budget() as usize;
        for slot in &self.ws {
            if let Ok(mut ws) = slot.lock() {
                ws.shrink_to(ws_budget);
            }
        }
        // a wire transport must re-form its process group at the new
        // width before the next collective (no-op for InProc) — this is
        // how scenario churn under `@tcp` sweep cells respawns ranks
        self.comm
            .transport
            .ensure_group(new_m.e)
            .map_err(|err| anyhow::Error::from(err).context("re-forming the transport group"))?;
        // grow the tracer's rank lanes if the group widened (shrinks keep
        // the departed ranks' history exportable)
        if let Some(tr) = &self.tracer {
            tr.lock().expect("tracer lock").ensure_ranks(new_m.e);
        }
        Ok(())
    }

    /// Rebuild this trainer from a pre-iteration snapshot after rank
    /// `dead`'s process died: one fewer live worker, re-sharded onto the
    /// largest divisor of hs that fits (attention clamps its own degree
    /// in the geometry resolution) — **the same path as
    /// kill/checkpoint/`--resume --e E'`** (`Trainer::new` with
    /// `e_override` + `checkpoint::restore_trainer`), which is what
    /// makes real-kill recovery bitwise equal to that oracle
    /// (tests/transport_faults.rs).  Zero survivors is the typed
    /// `NoViableWorkerCount`, never a panic.  The dead group's remaining
    /// processes are reaped when the old transport drops; the survivors'
    /// group spawns lazily at the retried iteration's first collective.
    fn recover_from_peer_death(
        &mut self,
        snap: &crate::checkpoint::Snapshot,
        dead: usize,
    ) -> Result<()> {
        let m = self.rt.manifest.model.clone();
        let avail = self.avail.saturating_sub(1);
        if avail == 0 {
            return Err(anyhow::Error::from(
                crate::contention::ScenarioError::NoViableWorkerCount {
                    avail: 0,
                    hs: m.hs,
                    heads: m.heads,
                },
            )
            .context(format!("rank {dead} process died; no workers left")));
        }
        let target = (1..=avail).rev().find(|d| m.hs % d == 0).unwrap_or(1);
        let mut cfg = self.cfg.clone();
        cfg.e_override = Some(target);
        let mut t = Trainer::new(cfg)?;
        crate::checkpoint::restore_trainer(&mut t, snap)
            .map_err(|err| anyhow::Error::from(err).context("restoring the recovery snapshot"))?;
        t.avail = avail;
        // carry the span history across the rebuild: the rebuilt trainer
        // made its own empty tracer — replace it (and Comm's clone) with
        // the one holding the run so far
        if let Some(tr) = self.tracer.take() {
            tr.lock().expect("tracer lock").ensure_ranks(t.model().e);
            t.comm.tracer = Some(tr.clone());
            t.tracer = Some(tr);
        }
        *self = t;
        self.trace_event(crate::trace::Kind::Churn, &format!("peer-died:r{dead}"), 0.0, 0);
        Ok(())
    }

    /// Fault injection (tests): SIGKILL the given rank's OS process.
    /// False when the transport has no process to kill (inproc, or the
    /// group has not spawned yet).
    pub fn debug_kill_rank(&mut self, rank: usize) -> bool {
        self.comm.transport.kill_rank(rank)
    }

    /// OS pid of the given rank's process (tests: SIGSTOP injection).
    pub fn debug_rank_pid(&self, rank: usize) -> Option<u32> {
        self.comm.transport.rank_pid(rank)
    }

    // -----------------------------------------------------------------
    // Tracing hooks (DESIGN.md §17) — pure mirrors of charges already
    // applied to the clocks; nothing here advances a clock, touches a
    // stat, or runs off the coordinator thread, so `--trace` cannot
    // perturb the simulation.
    // -----------------------------------------------------------------

    /// Mirror a compute charge on rank `w`: `dur` is the (χ-skewed)
    /// SimClock seconds just advanced, so the span starts `dur` before
    /// the rank's current clock.
    fn trace_compute(
        &self,
        w: usize,
        kind: crate::trace::Kind,
        label: &'static str,
        layer: i32,
        dur: f64,
        chi: f64,
    ) {
        if let Some(tr) = &self.tracer {
            tr.lock()
                .expect("tracer lock")
                .compute(w, kind, label, layer, self.clocks.now(w), dur, chi);
        }
    }

    /// Record a control event on the coordinator lane (rank 0): churn
    /// and memory transitions, checkpoints.  `dur == 0` is an instant
    /// pinned at the group frontier.
    fn trace_event(&self, kind: crate::trace::Kind, label: &str, dur: f64, bytes: u64) {
        if let Some(tr) = &self.tracer {
            let g = self.global_iter;
            let ipe = self.cfg.train.iters_per_epoch.max(1) as u64;
            tr.lock().expect("tracer lock").event(
                0,
                kind,
                label,
                g,
                (g / ipe) as u32,
                self.clocks.max(),
                dur,
                bytes,
            );
        }
    }

    // -----------------------------------------------------------------
    // One training iteration
    // -----------------------------------------------------------------

    pub fn train_iter(&mut self) -> Result<f32> {
        let m = self.rt.manifest.model.clone();
        let e = m.e;
        let g = self.global_iter;
        let ipe = self.cfg.train.iters_per_epoch.max(1) as u64;
        let (epoch, iter) = ((g / ipe) as usize, (g % ipe) as usize);
        let rt0 = self.clocks.max();
        // --- χ snapshot for this iteration.  The trace row is copied
        // into the injector on the coordinator before any rank work
        // launches; every charge (and wall-emulation sleep) this
        // iteration reads that snapshot.  Warmup stays homogeneous.
        if !self.warming {
            self.injector.set_iter_chi(self.trace.chis(g as usize));
            for &c in &self.injector.chi {
                self.epoch_chi_sum += c;
                self.epoch_chi_max = self.epoch_chi_max.max(c);
            }
            self.epoch_chi_iters += 1;
        }
        if let Some(tr) = &self.tracer {
            tr.lock().expect("tracer lock").begin_iter(
                g,
                epoch as u32,
                iter as u32,
                rt0,
                &self.injector.chi,
            );
        }
        let batch = match &self.forced_batch {
            Some(b) => b.clone(),
            None => self
                .data
                .train_batch(self.global_iter % self.cfg.train.train_batches as u64),
        };
        self.global_iter += 1;

        // --- balancing plan (uses last iteration's statistics)
        let mut replanned = false;
        let mut actions = match self.forced_actions.clone() {
            Some(a) => a,
            None => self.plan_actions(iter, &mut replanned)?,
        };
        self.enforce_degree_groups(&m, &mut actions);

        // --- memory accounting (DESIGN.md §16).  All charges are
        // *modeled* (plan-derived) footprints replayed on the
        // coordinator in rank order — never actual arena telemetry, so
        // the ledger's observables are bitwise thread-count-invariant.
        let mut recompute = vec![self.cfg.train.mem_recompute; e];
        let mut iter_mem = vec![0u64; e];
        if !self.warming {
            // predicted near-OOM: with a cached plan whose projected
            // footprint leaves less than NEAR_OOM_FRAC of some rank's
            // capacity free, force a drift-style replan *this* iteration
            // — the refreshed plan runs under the headroom filter set in
            // plan_now, steering migration away from the tight rank
            if self.forced_actions.is_none()
                && matches!(self.cfg.balancer.replan, ReplanMode::Online)
                && !replanned
                && self.mem_pressured(&m, &actions)
            {
                let a = self.plan_now()?;
                self.charge_replan();
                self.cached_actions = Some(a.clone());
                actions = a;
                self.enforce_degree_groups(&m, &mut actions);
                replanned = true;
            }
            self.ledger.begin_iter();
            let mut infeasible: Option<crate::memory::MemError> = None;
            for w in 0..e {
                let mig_in = mig_in_cols(&actions, w);
                let mut need = self.footprint.iter_bytes(&m, mig_in, recompute[w]);
                if !recompute[w] && need > self.ledger.headroom(w) {
                    // degrade before failing: per-rank activation
                    // checkpointing keeps one live layer instead of all
                    need = self.footprint.iter_bytes(&m, mig_in, true);
                    recompute[w] = true;
                }
                if need > self.ledger.headroom(w) && infeasible.is_none() {
                    infeasible = Some(crate::memory::MemError::Infeasible {
                        rank: w,
                        need_bytes: need,
                        headroom_bytes: self.ledger.headroom(w),
                    });
                }
                iter_mem[w] = need;
                self.ledger.charge(w, need);
            }
            if let Some(err) = infeasible {
                // leave a clean ledger behind the typed error: statics
                // stay resident, the attempted dynamics are rolled back
                for w in 0..e {
                    self.ledger.release(w, iter_mem[w]);
                }
                return Err(anyhow::Error::from(err)
                    .context(format!("planning iteration {g} exceeds the memory budget")));
            }
        }
        self.last_replanned = replanned;
        for a in &actions {
            for p in &a.layers {
                self.epoch_pruned_cols += p.pruned_cols(m.hs, m.ffl);
            }
            if let Some(mig) = &a.mig {
                self.epoch_migrated_cols += (mig.l_mig() * m.depth) as u64;
                // migrated cols are exact, not pruned: subtract them back
                self.epoch_pruned_cols =
                    self.epoch_pruned_cols.saturating_sub((mig.l_mig() * m.depth) as u64);
            }
        }

        // --- iteration timing starts here.  T_i is the rank's own
        // compute time (not post-barrier wall time — collectives sync all
        // clocks, which would hide the very skew Eq.(1) measures).
        self.clocks.take_iter_compute(); // reset per-iter compute counters
        let mut m_gemm = vec![0.0f64; e]; // per-rank block-GEMM time (M_i)

        // ---- forward -------------------------------------------------
        // embed (replicated): execute once, charge every rank
        let rep = self.state.rep.clone();
        let (outs, t) = self.call_wide(
            "embed_fwd",
            &[
                Arg::F32(&batch.patches),
                Arg::F32(&rep.w_patch),
                Arg::F32(&rep.pos),
                Arg::F32(&rep.cls),
            ],
        )?;
        let tc = self.sim_secs(t, timemodel::embed_s(&m, false));
        for r in 0..e {
            self.injector.charge_unskewed(&mut self.clocks, r, tc);
            self.trace_compute(r, crate::trace::Kind::Compute, "embed_fwd", -1, tc, 1.0);
        }
        let mut x = into1(outs)?;

        let mut attn_in: Vec<Tensor> = Vec::with_capacity(m.depth);
        let mut mlp_in: Vec<Tensor> = Vec::with_capacity(m.depth);
        for k in 0..m.depth {
            attn_in.push(x.clone());
            let mut partials = self.attn_fwd_partials(&x, k, &actions, &mut m_gemm)?;
            self.comm.all_reduce_group(&mut self.clocks, "attn_fwd", &mut partials, e)?;
            x.add_assign(&partials[0]);
            for (w, p) in partials.into_iter().enumerate() {
                self.recycle_rank(w, p);
            }

            mlp_in.push(x.clone());
            let mut partials = self.mlp_fwd_partials(&x, k, &actions, &mut m_gemm)?;
            self.comm.all_reduce_group(&mut self.clocks, "mlp_fwd", &mut partials, e)?;
            x.add_assign(&partials[0]);
            for (w, p) in partials.into_iter().enumerate() {
                self.recycle_rank(w, p);
            }
        }

        // ---- head (replicated fwd+bwd) --------------------------------
        let labels = batch.labels.clone();
        let (outs, t) = self.call_wide(
            "head_fwdbwd",
            &[
                Arg::F32(&x),
                Arg::F32(&rep.lnf_g),
                Arg::F32(&rep.lnf_b),
                Arg::F32(&rep.w_head),
                Arg::F32(&rep.b_head),
                Arg::I32(&labels),
            ],
        )?;
        let tc = self.sim_secs(t, timemodel::head_s(&m));
        for r in 0..e {
            self.injector.charge_unskewed(&mut self.clocks, r, tc);
            self.trace_compute(r, crate::trace::Kind::Compute, "head_fwdbwd", -1, tc, 1.0);
        }
        let mut it = outs.into_iter();
        let loss = it.next().unwrap().scalar_f32()?;
        let _ncorrect = it.next().unwrap().scalar_i32()?;
        let mut dy = it.next().unwrap().tensor()?;
        let dlnf_g = it.next().unwrap().tensor()?;
        let dlnf_b = it.next().unwrap().tensor()?;
        let dw_head = it.next().unwrap().tensor()?;
        let db_head = it.next().unwrap().tensor()?;

        // ---- backward --------------------------------------------------
        let mut block_grads = self.zeroed_block_grads();
        for k in (0..m.depth).rev() {
            let dpart = self.mlp_bwd(&mlp_in[k], &dy, k, &actions, &mut m_gemm, &mut block_grads)?;
            dy.add_assign(&dpart);
            self.recycle_rank(0, dpart);
            let dpart =
                self.attn_bwd(&attn_in[k], &dy, k, &actions, &mut m_gemm, &mut block_grads)?;
            dy.add_assign(&dpart);
            self.recycle_rank(0, dpart);
        }

        // embed bwd (replicated)
        let (outs, t) = self.call_wide(
            "embed_bwd",
            &[
                Arg::F32(&batch.patches),
                Arg::F32(&rep.w_patch),
                Arg::F32(&rep.pos),
                Arg::F32(&rep.cls),
                Arg::F32(&dy),
            ],
        )?;
        let tc = self.sim_secs(t, timemodel::embed_s(&m, true));
        for r in 0..e {
            self.injector.charge_unskewed(&mut self.clocks, r, tc);
            self.trace_compute(r, crate::trace::Kind::Compute, "embed_bwd", -1, tc, 1.0);
        }
        let mut it = outs.into_iter();
        let dw_patch = it.next().unwrap().tensor()?;
        let dpos = it.next().unwrap().tensor()?;
        let dcls = it.next().unwrap().tensor()?;

        // ---- imputation + optimizer ------------------------------------
        self.impute_and_step(&actions, &mut block_grads)?;
        let rep_grads: [(&str, &Tensor); 7] = [
            ("w_patch", &dw_patch),
            ("pos", &dpos),
            ("cls", &dcls),
            ("lnf_g", &dlnf_g),
            ("lnf_b", &dlnf_b),
            ("w_head", &dw_head),
            ("b_head", &db_head),
        ];
        for (name, g) in rep_grads {
            let p = self.state.rep.get_mut(name);
            self.opt.update(&format!("rep.{name}"), p, g);
        }

        // ---- buffer recycling -------------------------------------------
        // Per-rank grad sinks go back to their rank's workspace, the
        // replicated grads (and the spent dy chain) to the coordinator's —
        // next iteration's takes reuse them instead of allocating.
        for (w, per_rank) in block_grads.into_iter().enumerate() {
            let mut ws = self.ws[w].lock().expect("workspace lock poisoned");
            for bg in per_rank {
                ws.give_tensor(bg.ln1_g);
                ws.give_tensor(bg.ln1_b);
                ws.give_tensor(bg.wqkv);
                ws.give_tensor(bg.wo);
                ws.give_tensor(bg.ln2_g);
                ws.give_tensor(bg.ln2_b);
                ws.give_tensor(bg.w1);
                ws.give_tensor(bg.w2);
            }
        }
        for t in [dw_patch, dpos, dcls, dlnf_g, dlnf_b, dw_head, db_head, dy] {
            crate::runtime::recycle_local(t);
        }

        // ---- memory close-out -------------------------------------------
        if !self.warming {
            // activation checkpointing re-runs the forward GEMMs inside
            // the backward; charge the modeled surcharge *before* the
            // monitor records so the balancer prices recompute into its
            // next plan.  Numerics are untouched: recompute only moves
            // time, though adaptive strategies may legitimately replan
            // around the slower rank (under a stat-independent plan the
            // loss curve is bitwise invariant to the recompute decision).
            for w in 0..e {
                if recompute[w] {
                    let dt = crate::memory::RECOMPUTE_TIME_FRAC * m_gemm[w];
                    self.clocks.advance(w, dt);
                    self.trace_compute(w, crate::trace::Kind::Recompute, "recompute", -1, dt, 1.0);
                    m_gemm[w] += dt;
                }
            }
            self.epoch_recompute_iters += recompute.iter().filter(|&&r| r).count() as u64;
            // peak-usage stats while the iteration's dynamics are still
            // charged; then roll them back — only statics stay resident
            self.epoch_mem_hwm = self.epoch_mem_hwm.max(self.ledger.hwm_max());
            self.epoch_headroom_min =
                self.epoch_headroom_min.min(self.ledger.headroom_min());
            for w in 0..e {
                self.ledger.release(w, iter_mem[w]);
            }
        }

        // ---- statistics -------------------------------------------------
        let t_iter = self.clocks.take_iter_compute();
        if self.epoch_compute.len() == e {
            for (acc, t) in self.epoch_compute.iter_mut().zip(&t_iter) {
                *acc += t;
            }
        }
        // `--timeline` is a trace view: the tracer mirrored every compute
        // charge (same f64 values, same order as `iter_compute`), so the
        // sample it synthesizes here is bitwise identical to the one the
        // pre-trace sampler built from `t_iter` directly.
        if let Some(tr) = &self.tracer {
            let sample = tr
                .lock()
                .expect("tracer lock")
                .end_iter(self.clocks.max(), self.last_replanned);
            if let Some(s) = sample {
                self.report.timeline.push(s);
            }
        }
        self.monitor.record(t_iter, m_gemm);
        Ok(loss)
    }

    // -----------------------------------------------------------------
    // Replanning (DESIGN.md §12): when is the balancer's plan recomputed
    // -----------------------------------------------------------------

    /// Project a plan onto the fine-grained TP groups (DESIGN.md §18).
    /// Ranks outside a component's group hold zero-filled shard slots
    /// and never execute that component, so their plan fields reset to
    /// the no-op full plan (keeping the pruned-column accounting
    /// honest), and any migration touching an out-of-group rank is
    /// dropped whole — out-of-group shard columns are not model
    /// content, and a partially-received migration would leave
    /// un-imputed gradient holes.  A uniform degree vector is untouched,
    /// so every legacy run takes the early return.
    fn enforce_degree_groups(
        &self,
        m: &crate::runtime::manifest::ModelInfo,
        actions: &mut [WorkerAction],
    ) {
        let deg = m.degrees;
        if deg.is_uniform(m.e) {
            return;
        }
        for (w, a) in actions.iter_mut().enumerate() {
            if w >= deg.attn {
                for p in &mut a.layers {
                    p.attn_bucket = "g00".into();
                    p.attn_keep = (0..m.hs as u32).collect();
                }
            }
            if w >= deg.mlp {
                for p in &mut a.layers {
                    p.mlp_b1 = "g00".into();
                    p.mlp_b2 = "g00".into();
                    p.mlp_keep1 = (0..m.hs as u32).collect();
                    p.mlp_keep2 = (0..m.ffl as u32).collect();
                }
                a.mig = None;
            }
            if let Some(mig) = &a.mig {
                if mig.receivers.iter().any(|r| r.rank >= deg.mlp) {
                    a.mig = None;
                }
            }
        }
    }

    /// Produce this iteration's actions under the configured
    /// [`ReplanMode`].  `iter` is the within-epoch index; `replanned`
    /// reports whether the plan was recomputed this iteration.
    fn plan_actions(&mut self, iter: usize, replanned: &mut bool) -> Result<Vec<WorkerAction>> {
        match self.cfg.balancer.replan {
            // legacy engine: fresh plan (and detection statistics) every
            // iteration; no extra replan charge, preserving the paper
            // benches' accounting
            ReplanMode::Iter => {
                *replanned = true;
                self.plan_now()
            }
            // static per-epoch plan: recomputed at the boundary only —
            // the baseline the online controller is measured against
            ReplanMode::Epoch => {
                if iter == 0 || self.cached_actions.is_none() {
                    let a = self.plan_now()?;
                    self.charge_replan();
                    self.cached_actions = Some(a);
                    *replanned = true;
                }
                Ok(self.cached_actions.clone().expect("cached plan"))
            }
            // epoch boundaries + drift-triggered mid-epoch replans
            ReplanMode::Online => {
                let drift = self.controller.observe(&self.monitor.t_iter);
                if iter == 0 || drift.triggered || self.cached_actions.is_none() {
                    if drift.triggered {
                        // re-entrant pretest: refresh the Eq. 2/3 cost
                        // fits before re-running the allocation
                        self.refresh_costs();
                    }
                    let a = self.plan_now()?;
                    self.charge_replan();
                    self.cached_actions = Some(a);
                    *replanned = true;
                }
                Ok(self.cached_actions.clone().expect("cached plan"))
            }
        }
    }

    /// True when the projected footprint of `actions` leaves less than
    /// `NEAR_OOM_FRAC` of some rank's effective capacity free — the
    /// predictive trigger for a drift-style replan (DESIGN.md §16).
    /// Reads only ledger state (statics + squeezes) and plan-derived
    /// bytes, so the predicate is bitwise thread-count-invariant.
    fn mem_pressured(
        &self,
        m: &crate::runtime::manifest::ModelInfo,
        actions: &[WorkerAction],
    ) -> bool {
        (0..m.e).any(|w| {
            let need =
                self.footprint.iter_bytes(m, mig_in_cols(actions, w), self.cfg.train.mem_recompute);
            let slack = self.ledger.headroom(w).saturating_sub(need);
            (slack as f64) < self.ledger.effective_cap(w) as f64 * crate::memory::NEAR_OOM_FRAC
        })
    }

    /// One plan recomputation: gather the detection statistics the
    /// strategy needs (charged collectives) and run the balancer.
    fn plan_now(&mut self) -> Result<Vec<WorkerAction>> {
        let e = self.model().e;
        // refresh the balancer's migration-intake headroom: bytes each
        // rank can absorb beyond a plain iteration's dynamics.  At plan
        // time only statics are charged, so this is a pure function of
        // (cfg, E, fired squeeze events).  Warmup stays cap-agnostic.
        if self.warming {
            self.balancer.set_mem_headroom(None);
        } else {
            let m = self.rt.manifest.model.clone();
            let base = self.footprint.iter_bytes(&m, 0, false);
            let hr = (0..e).map(|w| self.ledger.headroom(w).saturating_sub(base)).collect();
            self.balancer.set_mem_headroom(Some(hr));
        }
        // detection statistics span the block-compute group only: ranks
        // outside both the attention and MLP groups run no block GEMMs,
        // and folding their near-idle runtimes into T_avg / T_min would
        // manufacture phantom demand on every member (DESIGN.md §18)
        let deg = self.rt.manifest.model.degrees;
        let g = deg.attn.max(deg.mlp);
        let t_avg = if matches!(self.cfg.balancer.strategy, Strategy::Mig | Strategy::Semi) {
            vec![0.0; e] // unused by MIG/SEMI
        } else {
            self.monitor.t_avg_group(&mut self.comm, &mut self.clocks, g)
        };
        let t_min = if matches!(self.cfg.balancer.strategy, Strategy::Mig | Strategy::Semi) {
            self.monitor.t_list_and_min_group(&mut self.comm, &mut self.clocks, g).1
        } else {
            0.0
        };
        let actions = self.balancer.plan_iter(
            &self.rt.manifest,
            &self.monitor,
            &t_avg,
            t_min,
            self.cfg.train.iters_per_epoch,
            &self.costs,
        );
        if !self.warming {
            self.epoch_replans += 1;
        }
        Ok(actions)
    }

    /// Charge the plan-recompute overhead Ω₁ to every rank's SimClock —
    /// replans are not free; the controller's RT wins must pay for them.
    /// (The detection collectives are already charged by `plan_now`.)
    fn charge_replan(&mut self) {
        let e = self.model().e;
        let dt = self.costs.omega1_s;
        let g = self.global_iter;
        let ipe = self.cfg.train.iters_per_epoch.max(1) as u64;
        for r in 0..e {
            self.clocks.advance_comm(r, dt);
            if let Some(tr) = &self.tracer {
                tr.lock().expect("tracer lock").event(
                    r,
                    crate::trace::Kind::Replan,
                    "replan",
                    g,
                    (g / ipe) as u32,
                    self.clocks.now(r),
                    dt,
                    0,
                );
            }
        }
    }

    /// Re-run the pretest cost fits mid-run (online replanning).
    /// Measured mode refits from the live timing profile and EWMA-blends
    /// into the standing fit to damp noise; modeled mode recomputes the
    /// deterministic fit (blending equal fits is the identity, keeping
    /// runs bitwise reproducible).
    fn refresh_costs(&mut self) {
        let fresh = self.fresh_cost_fit();
        self.costs = self.costs.blend(&fresh, 0.5);
    }

    /// The SimClock compute charge for one backend call: the measured
    /// seconds by default, the deterministic FLOP-model seconds under
    /// `--time-model modeled`.
    #[inline]
    fn sim_secs(&self, measured: f64, modeled: f64) -> f64 {
        match self.cfg.train.time_model {
            TimeModel::Measured => measured,
            TimeModel::Modeled => modeled,
        }
    }

    // ---- branch executions -------------------------------------------
    //
    // Each branch fans the E independent rank executables out on the
    // RankPool, then applies clock charges / M_i accounting / merges on
    // the coordinator thread in rank order — identical arithmetic to the
    // serial engine at any thread count.

    fn attn_fwd_partials(
        &mut self,
        x: &Tensor,
        k: usize,
        actions: &[WorkerAction],
        m_gemm: &mut [f64],
    ) -> Result<Vec<Tensor>> {
        // only the attention group's ranks (prefix 0..degrees.attn,
        // DESIGN.md §18) hold attention panels and execute; under
        // uniform degrees this is the full worker group
        let d = self.model().degrees.attn;
        let rt = &self.rt;
        let state = &self.state;
        let results = self.pool.run_ws(d, &self.ws, |w, ws| {
            let p = &actions[w].layers[k];
            let name = rt.manifest.attn_name("fwd", &p.attn_bucket);
            let idx: Vec<i32> = p.attn_keep.iter().map(|&i| i as i32).collect();
            let mask = ones_mask(idx.len(), ws);
            let b = &state.shards[w][k];
            let (outs, t) = rt.call_ws(
                &name,
                &[
                    Arg::F32(x),
                    Arg::F32(&b.ln1_g),
                    Arg::F32(&b.ln1_b),
                    Arg::F32(&b.wqkv),
                    Arg::F32(&b.wo),
                    Arg::I32(&idx),
                    Arg::F32(&mask),
                ],
                ws,
            )?;
            ws.give_tensor(mask);
            Ok((into1(outs)?, t))
        })?;
        let mut partials = Vec::with_capacity(d);
        let mi = &self.rt.manifest.model;
        for (w, (y, t)) in results.into_iter().enumerate() {
            let keep = actions[w].layers[k].attn_keep.len();
            let tc = self.sim_secs(t, timemodel::attn_s(mi, keep, false));
            self.injector.charge(&mut self.clocks, w, tc);
            let chi = self.injector.chi[w];
            let skewed = tc * chi;
            m_gemm[w] += skewed;
            self.trace_compute(w, crate::trace::Kind::Compute, "attn_fwd", k as i32, skewed, chi);
            partials.push(y);
        }
        Ok(partials)
    }

    fn mlp_fwd_partials(
        &mut self,
        x: &Tensor,
        k: usize,
        actions: &[WorkerAction],
        m_gemm: &mut [f64],
    ) -> Result<Vec<Tensor>> {
        // MLP group prefix 0..degrees.mlp (DESIGN.md §18); migration
        // stragglers and receivers are confined to it by
        // `enforce_degree_groups`, so `partials` indexing stays in range
        let d = self.model().degrees.mlp;
        let rt = &self.rt;
        let state = &self.state;
        let results = self.pool.run_ws(d, &self.ws, |w, ws| {
            let p = &actions[w].layers[k];
            let name = rt.manifest.mlp_name("fwd", &p.mlp_b1, &p.mlp_b2);
            let idx1: Vec<i32> = p.mlp_keep1.iter().map(|&i| i as i32).collect();
            let idx2: Vec<i32> = p.mlp_keep2.iter().map(|&i| i as i32).collect();
            let mask1 = ones_mask(idx1.len(), ws);
            let mask2 = ones_mask(idx2.len(), ws);
            let b = &state.shards[w][k];
            let (outs, t) = rt.call_ws(
                &name,
                &[
                    Arg::F32(x),
                    Arg::F32(&b.ln2_g),
                    Arg::F32(&b.ln2_b),
                    Arg::F32(&b.w1),
                    Arg::F32(&b.w2),
                    Arg::I32(&idx1),
                    Arg::F32(&mask1),
                    Arg::I32(&idx2),
                    Arg::F32(&mask2),
                ],
                ws,
            )?;
            ws.give_tensor(mask1);
            ws.give_tensor(mask2);
            Ok((into1(outs)?, t))
        })?;
        let mut partials = Vec::with_capacity(d);
        let mi = &self.rt.manifest.model;
        for (w, (y, t)) in results.into_iter().enumerate() {
            let p = &actions[w].layers[k];
            let (k1, k2) = (p.mlp_keep1.len(), p.mlp_keep2.len());
            let tc = self.sim_secs(t, timemodel::mlp_s(mi, k1, k2, false));
            self.injector.charge(&mut self.clocks, w, tc);
            let chi = self.injector.chi[w];
            let skewed = tc * chi;
            m_gemm[w] += skewed;
            self.trace_compute(w, crate::trace::Kind::Compute, "mlp_fwd", k as i32, skewed, chi);
            partials.push(y);
        }
        // migration: receivers compute stragglers' slices (fwd direction)
        self.run_migration(x, k, actions, m_gemm, &mut partials, None, None)?;
        Ok(partials)
    }

    fn mlp_bwd(
        &mut self,
        x_in: &Tensor,
        dy: &Tensor,
        k: usize,
        actions: &[WorkerAction],
        m_gemm: &mut [f64],
        block_grads: &mut [Vec<BlockGrads>],
    ) -> Result<Tensor> {
        let e = self.model().e;
        let d = self.model().degrees.mlp;
        let rt = &self.rt;
        let state = &self.state;
        let results = self.pool.run_ws(d, &self.ws, |w, ws| {
            let p = &actions[w].layers[k];
            let name = rt.manifest.mlp_name("bwd", &p.mlp_b1, &p.mlp_b2);
            let idx1: Vec<i32> = p.mlp_keep1.iter().map(|&i| i as i32).collect();
            let idx2: Vec<i32> = p.mlp_keep2.iter().map(|&i| i as i32).collect();
            let mask1 = ones_mask(idx1.len(), ws);
            let mask2 = ones_mask(idx2.len(), ws);
            let b = &state.shards[w][k];
            let (outs, t) = rt.call_ws(
                &name,
                &[
                    Arg::F32(x_in),
                    Arg::F32(&b.ln2_g),
                    Arg::F32(&b.ln2_b),
                    Arg::F32(&b.w1),
                    Arg::F32(&b.w2),
                    Arg::I32(&idx1),
                    Arg::F32(&mask1),
                    Arg::I32(&idx2),
                    Arg::F32(&mask2),
                    Arg::F32(dy),
                ],
                ws,
            )?;
            ws.give_tensor(mask1);
            ws.give_tensor(mask2);
            let mut it = outs.into_iter();
            Ok((
                it.next().unwrap().tensor()?,
                it.next().unwrap().tensor()?,
                it.next().unwrap().tensor()?,
                it.next().unwrap().tensor()?,
                it.next().unwrap().tensor()?,
                t,
            ))
        })?;
        let mut dx_parts = Vec::with_capacity(d);
        let mut dg_parts = Vec::with_capacity(d);
        let mut db_parts = Vec::with_capacity(d);
        let mi = &self.rt.manifest.model;
        for (w, (dx, dg, db, dw1, dw2, t)) in results.into_iter().enumerate() {
            let p = &actions[w].layers[k];
            let (k1, k2) = (p.mlp_keep1.len(), p.mlp_keep2.len());
            let tc = self.sim_secs(t, timemodel::mlp_s(mi, k1, k2, true));
            self.injector.charge(&mut self.clocks, w, tc);
            let chi = self.injector.chi[w];
            let skewed = tc * chi;
            m_gemm[w] += skewed;
            self.trace_compute(w, crate::trace::Kind::Compute, "mlp_bwd", k as i32, skewed, chi);
            dx_parts.push(dx);
            dg_parts.push(dg);
            db_parts.push(db);
            // swap the backend grads in; the zero placeholders return to
            // the rank's workspace
            let old = std::mem::replace(&mut block_grads[w][k].w1, dw1);
            self.recycle_rank(w, old);
            let old = std::mem::replace(&mut block_grads[w][k].w2, dw2);
            self.recycle_rank(w, old);
        }
        // migration backward: receivers compute grads of migrated slices
        self.run_migration(
            x_in,
            k,
            actions,
            m_gemm,
            &mut dx_parts,
            Some(dy),
            Some((&mut *block_grads, &mut dg_parts, &mut db_parts)),
        )?;
        // the dg/db/dx reduces are independent: batch them so a wire
        // transport overlaps their collective waits (Megatron's
        // column/row-parallel discipline).  Accounting replays the
        // sequential barrier/cost order and the copy-outs below only
        // read already-reduced data, so results are bitwise unchanged.
        // Under mixed degrees the reduce spans the MLP group only; ranks
        // outside it neither contribute nor wait.
        self.comm.all_reduce_group_batch(
            &mut self.clocks,
            "mlp_bwd",
            &mut [&mut dg_parts[..], &mut db_parts[..], &mut dx_parts[..]],
            e,
        )?;
        for w in 0..d {
            block_grads[w][k].ln2_g.data.copy_from_slice(&dg_parts[0].data);
            block_grads[w][k].ln2_b.data.copy_from_slice(&db_parts[0].data);
        }
        for (w, p) in dg_parts.into_iter().enumerate() {
            self.recycle_rank(w, p);
        }
        for (w, p) in db_parts.into_iter().enumerate() {
            self.recycle_rank(w, p);
        }
        let mut it = dx_parts.into_iter().enumerate();
        let (_, first) = it.next().expect("at least one rank");
        for (w, p) in it {
            self.recycle_rank(w, p);
        }
        Ok(first)
    }

    fn attn_bwd(
        &mut self,
        x_in: &Tensor,
        dy: &Tensor,
        k: usize,
        actions: &[WorkerAction],
        m_gemm: &mut [f64],
        block_grads: &mut [Vec<BlockGrads>],
    ) -> Result<Tensor> {
        let e = self.model().e;
        let d = self.model().degrees.attn;
        let rt = &self.rt;
        let state = &self.state;
        let results = self.pool.run_ws(d, &self.ws, |w, ws| {
            let p = &actions[w].layers[k];
            let name = rt.manifest.attn_name("bwd", &p.attn_bucket);
            let idx: Vec<i32> = p.attn_keep.iter().map(|&i| i as i32).collect();
            let mask = ones_mask(idx.len(), ws);
            let b = &state.shards[w][k];
            let (outs, t) = rt.call_ws(
                &name,
                &[
                    Arg::F32(x_in),
                    Arg::F32(&b.ln1_g),
                    Arg::F32(&b.ln1_b),
                    Arg::F32(&b.wqkv),
                    Arg::F32(&b.wo),
                    Arg::I32(&idx),
                    Arg::F32(&mask),
                    Arg::F32(dy),
                ],
                ws,
            )?;
            ws.give_tensor(mask);
            let mut it = outs.into_iter();
            Ok((
                it.next().unwrap().tensor()?,
                it.next().unwrap().tensor()?,
                it.next().unwrap().tensor()?,
                it.next().unwrap().tensor()?,
                it.next().unwrap().tensor()?,
                t,
            ))
        })?;
        let mut dx_parts = Vec::with_capacity(d);
        let mut dg_parts = Vec::with_capacity(d);
        let mut db_parts = Vec::with_capacity(d);
        let mi = &self.rt.manifest.model;
        for (w, (dx, dg, db, dwqkv, dwo, t)) in results.into_iter().enumerate() {
            let keep = actions[w].layers[k].attn_keep.len();
            let tc = self.sim_secs(t, timemodel::attn_s(mi, keep, true));
            self.injector.charge(&mut self.clocks, w, tc);
            let chi = self.injector.chi[w];
            let skewed = tc * chi;
            m_gemm[w] += skewed;
            self.trace_compute(w, crate::trace::Kind::Compute, "attn_bwd", k as i32, skewed, chi);
            dx_parts.push(dx);
            dg_parts.push(dg);
            db_parts.push(db);
            let old = std::mem::replace(&mut block_grads[w][k].wqkv, dwqkv);
            self.recycle_rank(w, old);
            let old = std::mem::replace(&mut block_grads[w][k].wo, dwo);
            self.recycle_rank(w, old);
        }
        // batched like mlp_bwd: overlapped waits, bitwise-identical
        // accounting and sums; spans the attention group only
        self.comm.all_reduce_group_batch(
            &mut self.clocks,
            "attn_bwd",
            &mut [&mut dg_parts[..], &mut db_parts[..], &mut dx_parts[..]],
            e,
        )?;
        for w in 0..d {
            block_grads[w][k].ln1_g.data.copy_from_slice(&dg_parts[0].data);
            block_grads[w][k].ln1_b.data.copy_from_slice(&db_parts[0].data);
        }
        for (w, p) in dg_parts.into_iter().enumerate() {
            self.recycle_rank(w, p);
        }
        for (w, p) in db_parts.into_iter().enumerate() {
            self.recycle_rank(w, p);
        }
        let mut it = dx_parts.into_iter().enumerate();
        let (_, first) = it.next().expect("at least one rank");
        for (w, p) in it {
            self.recycle_rank(w, p);
        }
        Ok(first)
    }

    /// Execute migration receiver slices for every straggler's plan at
    /// block k.  Fwd when `dy` is None, bwd otherwise (`bwd` carries the
    /// gradient sinks and must be Some exactly when `dy` is).  Partials
    /// merge into `partials[receiver]` (reduce-merging) or are sent back
    /// to the straggler (scatter-gather / merging disabled).
    ///
    /// Receiver slices across all stragglers are independent, so they run
    /// concurrently on the pool; weight-movement collectives, clock
    /// charges, and merges replay afterwards in the serial engine's exact
    /// nested order (straggler → receiver → chunk).
    #[allow(clippy::type_complexity)]
    fn run_migration(
        &mut self,
        x: &Tensor,
        k: usize,
        actions: &[WorkerAction],
        m_gemm: &mut [f64],
        partials: &mut [Tensor],
        dy: Option<&Tensor>,
        mut bwd: Option<(&mut [Vec<BlockGrads>], &mut Vec<Tensor>, &mut Vec<Tensor>)>,
    ) -> Result<()> {
        debug_assert_eq!(dy.is_some(), bwd.is_some(), "dy and bwd sinks travel together");
        let m = self.rt.manifest.model.clone();
        // job list in replay order: (straggler, receiver rank, chunk)
        let mut jobs: Vec<(usize, usize, Chunk)> = Vec::new();
        for w in 0..m.e {
            let Some(mig) = &actions[w].mig else { continue };
            for rw in &mig.receivers {
                for chunk in &rw.chunks {
                    jobs.push((w, rw.rank, chunk.clone()));
                }
            }
        }
        if jobs.is_empty() {
            return Ok(());
        }

        // ---- concurrent slice execution (compute only, no shared state).
        // Each job computes with its *receiver* rank's workspace — that is
        // the rank whose SimClock is charged for the slice.
        let rt = &self.rt;
        let state = &self.state;
        let ws_slots = &self.ws;
        let outs = self.pool.run(jobs.len(), |j| {
            let (w, receiver, chunk) = &jobs[j];
            let mig = actions[*w].mig.as_ref().expect("job built from a plan");
            let cols: Vec<u32> = mig.migrated[chunk.start..chunk.start + chunk.len].to_vec();
            let shard = &state.shards[*w][k];
            let w1c = shard.w1.gather_cols(&cols).pad_cols(chunk.kb);
            let w2c = shard.w2.gather_rows(&cols).pad_rows(chunk.kb);
            // Prefer the receiver rank's arena, but never *block* on it:
            // two chunks for the same receiver run concurrently on the
            // pool, and serializing them on the Mutex would undo the
            // PR-2 migration-phase parallelism.  The throwaway fallback
            // allocates, but only on the contended (rare) path.
            let mut fallback = Workspace::new();
            let mut guard = ws_slots[*receiver].try_lock();
            let ws: &mut Workspace = match guard {
                Ok(ref mut g) => g,
                Err(_) => &mut fallback,
            };
            match dy {
                None => {
                    let name = rt.manifest.mig_name("fwd", chunk.kb);
                    let (outs, t) = rt.call_ws(
                        &name,
                        &[
                            Arg::F32(x),
                            Arg::F32(&shard.ln2_g),
                            Arg::F32(&shard.ln2_b),
                            Arg::F32(&w1c),
                            Arg::F32(&w2c),
                        ],
                        ws,
                    )?;
                    Ok((MigOut::Fwd(into1(outs)?), t))
                }
                Some(dy) => {
                    let name = rt.manifest.mig_name("bwd", chunk.kb);
                    let (outs, t) = rt.call_ws(
                        &name,
                        &[
                            Arg::F32(x),
                            Arg::F32(&shard.ln2_g),
                            Arg::F32(&shard.ln2_b),
                            Arg::F32(&w1c),
                            Arg::F32(&w2c),
                            Arg::F32(dy),
                        ],
                        ws,
                    )?;
                    let mut it = outs.into_iter();
                    Ok((
                        MigOut::Bwd {
                            dx: it.next().unwrap().tensor()?,
                            dg: it.next().unwrap().tensor()?,
                            db: it.next().unwrap().tensor()?,
                            dw1c: it.next().unwrap().tensor()?,
                            dw2c: it.next().unwrap().tensor()?,
                        },
                        t,
                    ))
                }
            }
        })?;

        // ---- serial replay: collectives, charges, merges in rank order
        let policy = self.cfg.balancer.mig_policy;
        let merging =
            self.cfg.balancer.reduce_merging && policy == MigPolicy::BroadcastReduce;
        let msg_bytes = m.bs * m.seq * m.hs * 4;
        let mut results = outs.into_iter();
        for w in 0..m.e {
            let Some(mig) = actions[w].mig.clone() else { continue };
            let receivers: Vec<usize> = mig.receivers.iter().map(|r| r.rank).collect();
            // weight movement (fwd only — receivers keep them for bwd)
            if dy.is_none() {
                match policy {
                    MigPolicy::BroadcastReduce => self.comm.broadcast(
                        &mut self.clocks,
                        w,
                        &receivers,
                        mig.weight_bytes(m.hs),
                    ),
                    MigPolicy::ScatterGather => {
                        let per = mig.weight_bytes(m.hs) / receivers.len().max(1);
                        self.comm.scatter(&mut self.clocks, w, &receivers, per);
                    }
                }
            }
            for rw in &mig.receivers {
                for chunk in &rw.chunks {
                    let (out, t) = results.next().expect("one result per migration job");
                    // The slice above may have computed in a throwaway
                    // arena (the try_lock fallback) whose high-water mark
                    // used to vanish without ever folding into
                    // `mem_hwm_bytes`.  Whether the fallback fired is
                    // thread-timing-dependent, so the ledger instead
                    // records the same modeled per-chunk scratch bound on
                    // every run — weight panels in plus the activation
                    // slice out, released as soon as the chunk's replay
                    // merges — charged to the receiver that owned the
                    // arena.
                    if !self.warming {
                        let scratch =
                            chunk.kb as u64 * crate::memory::mig_bytes_per_col(&m);
                        self.ledger.charge(rw.rank, scratch);
                        self.ledger.release(rw.rank, scratch);
                    }
                    let bwd = dy.is_some();
                    let tc = self.sim_secs(t, timemodel::mig_slice_s(&m, chunk.kb, bwd));
                    self.injector.charge(&mut self.clocks, rw.rank, tc);
                    let chi = self.injector.chi[rw.rank];
                    let skewed = tc * chi;
                    m_gemm[rw.rank] += skewed;
                    self.trace_compute(
                        rw.rank,
                        crate::trace::Kind::Compute,
                        "mig_slice",
                        k as i32,
                        skewed,
                        chi,
                    );
                    match out {
                        MigOut::Fwd(y) => {
                            if merging {
                                partials[rw.rank].add_assign(&y);
                            } else {
                                // explicit collection back to the straggler
                                self.comm.gather(&mut self.clocks, w, &[rw.rank], msg_bytes);
                                partials[w].add_assign(&y);
                            }
                            self.recycle_rank(rw.rank, y);
                        }
                        MigOut::Bwd { dx, dg, db, dw1c, dw2c } => {
                            let (block_grads, dg_parts, db_parts) =
                                bwd.as_mut().expect("bwd sinks present for bwd jobs");
                            if merging {
                                partials[rw.rank].add_assign(&dx);
                                dg_parts[rw.rank].add_assign(&dg);
                                db_parts[rw.rank].add_assign(&db);
                            } else {
                                self.comm.gather(&mut self.clocks, w, &[rw.rank], msg_bytes);
                                partials[w].add_assign(&dx);
                                dg_parts[w].add_assign(&dg);
                                db_parts[w].add_assign(&db);
                            }
                            // compact weight grads always return (small)
                            self.comm.gather(
                                &mut self.clocks,
                                w,
                                &[rw.rank],
                                2 * m.hs * chunk.len * 4,
                            );
                            let cols: Vec<u32> =
                                mig.migrated[chunk.start..chunk.start + chunk.len].to_vec();
                            let dw1 = dw1c.take_cols(chunk.len);
                            let dw2 = dw2c.take_rows(chunk.len);
                            block_grads[w][k].w1.scatter_cols_assign(&cols, &dw1);
                            block_grads[w][k].w2.scatter_rows_assign(&cols, &dw2);
                            for t in [dx, dg, db, dw1c, dw2c, dw1, dw2] {
                                self.recycle_rank(rw.rank, t);
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Apply imputation policies to pruned grad positions, then SGD.
    fn impute_and_step(
        &mut self,
        actions: &[WorkerAction],
        block_grads: &mut [Vec<BlockGrads>],
    ) -> Result<()> {
        let m = self.rt.manifest.model.clone();
        let policy = self.cfg.balancer.imputation;
        for w in 0..m.e {
            // component-group membership (DESIGN.md §18): ranks outside a
            // group hold zero-filled slots there — no imputation, no
            // optimizer step, no momentum buffers (the checkpoint and the
            // elastic re-shard both treat those keys as absent)
            let attn_member = w < m.degrees.attn;
            let mlp_member = w < m.degrees.mlp;
            for k in 0..m.depth {
                let p = &actions[w].layers[k];
                let g = &mut block_grads[w][k];
                let prev = self.prev_grads.as_ref().map(|pg| &pg[w][k]);
                if attn_member {
                    // qkv contraction rows
                    let lin = Lineage::new(m.hs, &p.attn_keep);
                    impute_rows(&mut g.wqkv, &lin, policy, prev.map(|p| &p.wqkv));
                }
                if mlp_member {
                    // fc1 contraction rows
                    let lin1 = Lineage::new(m.hs, &p.mlp_keep1);
                    impute_rows(&mut g.w1, &lin1, policy, prev.map(|p| &p.w1));
                    // ffl dim: pruned = complement of keep2 MINUS migrated
                    // (migrated grads arrived exactly via scatter)
                    let mut lin2 = Lineage::new(m.ffl, &p.mlp_keep2);
                    if let Some(mig) = &actions[w].mig {
                        let migset: std::collections::BTreeSet<u32> =
                            mig.migrated.iter().copied().collect();
                        lin2.pruned.retain(|i| !migset.contains(i));
                    }
                    impute_cols(&mut g.w1, &lin2, policy, prev.map(|p| &p.w1));
                    impute_rows(&mut g.w2, &lin2, policy, prev.map(|p| &p.w2));
                }
                // optimizer
                let b = &mut self.state.shards[w][k];
                for name in crate::model::BlockShard::names() {
                    if w >= crate::model::shard_degree(&m, name) {
                        continue;
                    }
                    let key = format!("{w}.{k}.{name}");
                    self.opt.update(&key, b.get_mut(name), g.get(name));
                }
            }
        }
        if let Some(pg) = &mut self.prev_grads {
            for w in 0..m.e {
                for k in 0..m.depth {
                    pg[w][k] = block_grads[w][k].clone();
                }
            }
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Evaluation (full-width forward; not charged to RT)
    // -----------------------------------------------------------------

    pub fn eval(&mut self) -> Result<(f64, f64)> {
        let m = self.rt.manifest.model.clone();
        let mut loss_sum = 0.0;
        let mut correct = 0i64;
        let mut total = 0i64;
        for i in 0..self.cfg.train.eval_iters {
            let batch = match &self.forced_batch {
                Some(b) => b.clone(),
                None => self.data.eval_batch(i as u64),
            };
            let x = self.forward_full(&batch)?;
            let (outs, _) = self.rt.call(
                "head_infer",
                &[
                    Arg::F32(&x),
                    Arg::F32(&self.state.rep.lnf_g),
                    Arg::F32(&self.state.rep.lnf_b),
                    Arg::F32(&self.state.rep.w_head),
                    Arg::F32(&self.state.rep.b_head),
                    Arg::I32(&batch.labels),
                ],
            )?;
            loss_sum += outs[0].scalar_f32()? as f64;
            correct += outs[1].scalar_i32()? as i64;
            total += m.bs as i64;
        }
        Ok((
            loss_sum / self.cfg.train.eval_iters as f64,
            correct as f64 / total as f64,
        ))
    }

    /// Unpruned forward pass (eval / golden checks). No clock charges.
    /// Per-rank shards run on the pool; partials fold in rank order, so
    /// the result is thread-count-invariant like the training path.
    pub fn forward_full(&mut self, batch: &Batch) -> Result<Tensor> {
        let m = self.rt.manifest.model.clone();
        let rep = self.state.rep.clone();
        let (outs, _) = self.rt.call(
            "embed_fwd",
            &[
                Arg::F32(&batch.patches),
                Arg::F32(&rep.w_patch),
                Arg::F32(&rep.pos),
                Arg::F32(&rep.cls),
            ],
        )?;
        let mut x = into1(outs)?;
        let idx_hs: Vec<i32> = (0..m.hs as i32).collect();
        let idx_ffl: Vec<i32> = (0..m.ffl as i32).collect();
        let ones_hs = Tensor::full(&[m.hs], 1.0);
        let ones_ffl = Tensor::full(&[m.ffl], 1.0);
        let rt = &self.rt;
        let state = &self.state;
        // (embed above ran at width 1 — it's outside the hot loop; the
        // per-rank full-width calls below use the pool instead)
        for k in 0..m.depth {
            let xin = &x;
            // members only (DESIGN.md §18): out-of-group shards are
            // zero-filled slots, so their partials are pure wasted work
            let parts = self.pool.run_ws(m.degrees.attn, &self.ws, |w, ws| {
                let b = &state.shards[w][k];
                let (outs, _) = rt.call_ws(
                    "attn_fwd_g00",
                    &[
                        Arg::F32(xin),
                        Arg::F32(&b.ln1_g),
                        Arg::F32(&b.ln1_b),
                        Arg::F32(&b.wqkv),
                        Arg::F32(&b.wo),
                        Arg::I32(&idx_hs),
                        Arg::F32(&ones_hs),
                    ],
                    ws,
                )?;
                into1(outs)
            })?;
            self.fold_partials_into(&mut x, parts);
            let xin = &x;
            let parts = self.pool.run_ws(m.degrees.mlp, &self.ws, |w, ws| {
                let b = &state.shards[w][k];
                let (outs, _) = rt.call_ws(
                    "mlp_fwd_g00",
                    &[
                        Arg::F32(xin),
                        Arg::F32(&b.ln2_g),
                        Arg::F32(&b.ln2_b),
                        Arg::F32(&b.w1),
                        Arg::F32(&b.w2),
                        Arg::I32(&idx_hs),
                        Arg::F32(&ones_hs),
                        Arg::I32(&idx_ffl),
                        Arg::F32(&ones_ffl),
                    ],
                    ws,
                )?;
                into1(outs)
            })?;
            self.fold_partials_into(&mut x, parts);
        }
        Ok(x)
    }

    /// Fold rank partials into `x` in rank order (the deterministic
    /// reduction the serial engine used for full-width forwards), then
    /// recycle every partial buffer to its rank's workspace.
    fn fold_partials_into(&self, x: &mut Tensor, parts: Vec<Tensor>) {
        let mut it = parts.into_iter().enumerate();
        let (_, mut acc) = it.next().expect("at least one rank partial");
        for (w, p) in it {
            acc.add_assign(&p);
            self.recycle_rank(w, p);
        }
        x.add_assign(&acc);
        self.recycle_rank(0, acc);
    }
}

/// If `err`'s root cause is `TransportError::PeerDied`, the rank that
/// died — the one transport failure the trainer can recover from
/// in-place (everything else propagates to the caller).
fn peer_died_rank(err: &anyhow::Error) -> Option<usize> {
    match err.downcast_ref::<crate::collectives::transport::TransportError>() {
        Some(crate::collectives::transport::TransportError::PeerDied { rank }) => Some(*rank),
        _ => None,
    }
}

/// Migrated columns landing on `rank` under `actions` — one layer's
/// working set (slices are broadcast and processed layer-at-a-time), so
/// the ledger charge mirrors the balancer-side `mig_bytes_per_col`
/// headroom check exactly.
fn mig_in_cols(actions: &[WorkerAction], rank: usize) -> u64 {
    actions
        .iter()
        .filter_map(|a| a.mig.as_ref())
        .map(|p| p.cols_for(rank) as u64)
        .sum()
}

/// Resolve the manifest for `cfg.model` at worker count `e` under the
/// run's fine-grained degree configuration (DESIGN.md §18).  This is the
/// single geometry-resolution path shared by `Trainer::new`, the live
/// churn/OOM transitions, and the elastic checkpoint restore — sharing
/// it is what keeps a live transition bitwise equal to the
/// kill/checkpoint/`--resume` oracle when degrees are in play.
///
/// Order of precedence per component: explicit `--e-*` override, then
/// `--degrees auto` (balancer selection from the iteration-0 χ row and
/// the modeled network), then the uniform `e` default.  The resolved
/// vector is clamped onto `e` with [`presets::clamp_degrees`] — a churn
/// transition to a narrower group degrades each component to its nearest
/// valid divisor instead of erroring.
pub(crate) fn resolved_manifest(
    cfg: &RunCfg,
    e: usize,
) -> Result<crate::runtime::manifest::Manifest> {
    use crate::runtime::presets;
    if !cfg.degree_overrides.any() && !cfg.degrees_auto {
        return presets::synthesize_with_e(&cfg.model, e);
    }
    let base = presets::synthesize_with_e(&cfg.model, e)?;
    let m0 = base.model.clone();
    let mut want = if cfg.degrees_auto {
        let chis = cfg.stragglers.chis_at(e, 0, 0);
        crate::balancer::select_degrees(&m0, &chis, &CostModel::from_net(cfg.net))
    } else {
        crate::runtime::manifest::Degrees::uniform(e)
    };
    let ov = &cfg.degree_overrides;
    if let Some(d) = ov.embed {
        want.embed = d;
    }
    if let Some(d) = ov.attn {
        want.attn = d;
    }
    if let Some(d) = ov.mlp {
        want.mlp = d;
    }
    if let Some(d) = ov.head {
        want.head = d;
    }
    let degrees = presets::clamp_degrees(m0.hs, m0.heads, want, e);
    presets::synthesize_with_degrees(&cfg.model, e, degrees)
}

/// Drain a wall-clock segment: elapsed seconds since `w`, resetting `w`
/// to now (epoch wall accounting across checkpoint/kill boundaries).
fn take_wall(w: &mut std::time::Instant) -> f64 {
    let dt = w.elapsed().as_secs_f64();
    *w = std::time::Instant::now();
    dt
}

/// One migration receiver slice's computed outputs (pre-merge).
enum MigOut {
    Fwd(Tensor),
    Bwd { dx: Tensor, dg: Tensor, db: Tensor, dw1c: Tensor, dw2c: Tensor },
}

fn into1(outs: Vec<Out>) -> Result<Tensor> {
    outs.into_iter().next().context("no outputs")?.tensor()
}

/// All-ones keep mask in a workspace buffer (return it with
/// `ws.give_tensor` after the call).
fn ones_mask(len: usize, ws: &mut Workspace) -> Tensor {
    let mut v = ws.take(len);
    v.fill(1.0);
    Tensor::from_vec(&[len], v)
}
