//! The scoped rank-execution pool behind the parallel trainer.
//!
//! One simulated iteration fans the E ranks' independent work (branch
//! executables, migration receiver slices) out over OS threads and joins
//! at the existing collective boundaries.  Determinism is preserved by
//! construction, not by luck:
//!
//! * workers only *compute* — every mutation of shared trainer state
//!   (SimClock charges, `m_gemm` accounting, partial-sum merging, comm
//!   stats) happens afterwards on the coordinator thread, in rank order,
//!   exactly as the serial engine did;
//! * results come back indexed by rank, so reductions consume them in a
//!   fixed order no matter which worker finished first;
//! * workers run their kernels under [`linalg::with_gemm_threads`]`(1, ..)`
//!   so rank-level and GEMM-level parallelism never stack up on the same
//!   cores.
//!
//! With `threads == 1` the pool degenerates to an inline loop over the
//! same closure — the 1-thread and N-thread paths execute identical
//! arithmetic, which is what `tests/parallel_determinism.rs` pins
//! bitwise.
//!
//! This pool is the **compute plane** only.  The **data plane** — how
//! all-reduce payloads actually move between ranks — lives behind the
//! [`crate::collectives::transport::Transport`] seam (DESIGN.md §15):
//! with `--transport tcp` the same per-rank closures run here, threads
//! overlap the wire wait, and only the reduction bytes travel through
//! rank OS processes.  The two axes compose freely, which is why the
//! cross-transport parity suite runs at `--threads` 1 and 4 alike.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::tensor::linalg;
use crate::tensor::Workspace;

/// Resolve a `--threads` request: `0` means "all available cores"
/// (cached — `available_parallelism` is not re-queried per call).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        linalg::available_cores()
    } else {
        requested
    }
}

/// A fixed-width pool of scoped worker threads for per-rank jobs.
///
/// `std::thread::scope` keeps everything borrow-checked against the
/// trainer's state (no `'static` bounds, no new dependencies); workers
/// pull job indices from a shared atomic counter, so a straggling rank
/// with a pruned (cheap) executable doesn't idle a whole thread.
///
/// Trade-off: each [`RankPool::run`] spawns and joins fresh OS threads
/// (~tens of µs per worker) rather than keeping a persistent
/// channel-fed pool.  That overhead is noise for the kernels that
/// dominate the fig5–fig11 / e2e models, and zero at `threads == 1`; if
/// a future workload fans out sub-100µs jobs per phase, replace the
/// scope with a long-lived worker + job-channel design.
#[derive(Debug)]
pub struct RankPool {
    threads: usize,
}

impl RankPool {
    pub fn new(requested: usize) -> RankPool {
        RankPool { threads: resolve_threads(requested) }
    }

    /// The resolved worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), …, f(n-1)` concurrently and return the results in
    /// index order.  Errors propagate deterministically: the lowest-index
    /// failure wins regardless of completion order.  A panicking job
    /// propagates the panic to the caller.
    pub fn run<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        let workers = self.threads.min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let mut slots: Vec<Option<Result<T>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(workers);
            for _ in 0..workers {
                let next = &next;
                let f = &f;
                handles.push(s.spawn(move || {
                    let mut done: Vec<(usize, Result<T>)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        // nested GEMM fan-out would oversubscribe the
                        // pool's cores — rank jobs run kernels serially
                        done.push((i, linalg::with_gemm_threads(1, || f(i))));
                    }
                    done
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(done) => {
                        for (i, r) in done {
                            slots[i] = Some(r);
                        }
                    }
                    // re-raise the worker's panic with its original payload
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        });
        slots
            .into_iter()
            .map(|slot| slot.expect("rank job never ran"))
            .collect()
    }

    /// [`RankPool::run`] with one [`Workspace`] slot per job: job `i`
    /// gets exclusive access to `ws[i]` for its whole duration, so every
    /// rank reuses its own scratch arena across phases and iterations
    /// (the zero-alloc steady-state path).  `ws.len()` must cover `n`.
    ///
    /// Determinism is unaffected: workspace buffers are checked out
    /// zero-filled, so which iteration's memory a rank reuses can never
    /// leak into results.
    pub fn run_ws<T, F>(&self, n: usize, ws: &[Mutex<Workspace>], f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize, &mut Workspace) -> Result<T> + Sync,
    {
        assert!(ws.len() >= n, "need one workspace slot per job ({} < {n})", ws.len());
        let workers = self.threads.min(n);
        if workers <= 1 {
            return (0..n)
                .map(|i| {
                    let mut guard = ws[i].lock().expect("workspace lock poisoned");
                    f(i, &mut guard)
                })
                .collect();
        }
        let next = AtomicUsize::new(0);
        let mut slots: Vec<Option<Result<T>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(workers);
            for _ in 0..workers {
                let next = &next;
                let f = &f;
                handles.push(s.spawn(move || {
                    let mut done: Vec<(usize, Result<T>)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        // job i owns workspace slot i; serial GEMMs so
                        // rank- and GEMM-level fan-out never stack
                        let mut guard = ws[i].lock().expect("workspace lock poisoned");
                        done.push((i, linalg::with_gemm_threads(1, || f(i, &mut guard))));
                    }
                    done
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(done) => {
                        for (i, r) in done {
                            slots[i] = Some(r);
                        }
                    }
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        });
        slots
            .into_iter()
            .map(|slot| slot.expect("rank job never ran"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::bail;

    #[test]
    fn results_come_back_in_index_order() {
        for threads in [1usize, 2, 4, 9] {
            let pool = RankPool::new(threads);
            let out = pool.run(17, |i| Ok(i * i)).unwrap();
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn lowest_index_error_wins() {
        let pool = RankPool::new(4);
        let err = pool
            .run(8, |i| {
                if i % 2 == 1 {
                    bail!("job {i} failed")
                }
                Ok(i)
            })
            .unwrap_err();
        assert_eq!(err.to_string(), "job 1 failed");
    }

    #[test]
    fn zero_requests_resolve_to_at_least_one() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert!(RankPool::new(0).threads() >= 1);
    }

    #[test]
    fn empty_job_list_is_fine() {
        let pool = RankPool::new(4);
        let out: Vec<usize> = pool.run(0, |_| unreachable!()).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn workers_see_serial_gemm_override() {
        let pool = RankPool::new(2);
        let widths = pool.run(4, |_| Ok(linalg::gemm_threads())).unwrap();
        assert!(widths.iter().all(|&w| w == 1), "workers must not nest GEMM fan-out");
    }

    #[test]
    fn run_ws_pins_one_workspace_slot_per_job_and_reuses_it() {
        for threads in [1usize, 3] {
            let pool = RankPool::new(threads);
            let ws: Vec<Mutex<Workspace>> = (0..6).map(|_| Mutex::new(Workspace::new())).collect();
            for _ in 0..3 {
                let out = pool
                    .run_ws(6, &ws, |i, w| {
                        let buf = w.take(64 + i);
                        w.give(buf);
                        Ok(i)
                    })
                    .unwrap();
                assert_eq!(out, (0..6).collect::<Vec<_>>());
            }
            for slot in &ws {
                let g = slot.lock().unwrap();
                assert_eq!(g.alloc_count(), 1, "slot must allocate once, then reuse");
                assert_eq!(g.take_count(), 3);
            }
        }
    }
}
