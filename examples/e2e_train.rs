//! END-TO-END VALIDATION (DESIGN.md §5, EXPERIMENTS.md §E2E): train the
//! ~100M-parameter ViT (hs=768, depth=12, seq=65) with e=4 tensor-parallel
//! workers on the synthetic dataset, a χ=2 straggler appearing mid-run,
//! SEMI-migration balancing on.  Logs the full loss curve and per-epoch
//! RT/ACC, proving every layer composes: Pallas kernel → JAX shard
//! programs → HLO artifacts → PJRT runtime → Rust coordinator
//! (collectives, resizing, migration, optimizer).
//!
//! Run: `cargo run --release --example e2e_train -- [--iters N] [--epochs M]`
//! (defaults sized for a single-core CPU testbed; scale up at will)

use anyhow::Result;
use flextp::config::{parse_kv_args, RunCfg, StragglerPlan, Strategy};
use flextp::train::trainer::Trainer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (_, kv) = parse_kv_args(&args)?;
    let model = kv.get("model").map(String::as_str).unwrap_or("vit-100m");
    let epochs: usize = kv.get("epochs").map(|s| s.parse().unwrap()).unwrap_or(6);
    let iters: usize = kv.get("iters").map(|s| s.parse().unwrap()).unwrap_or(8);

    let mut cfg = RunCfg::new(model);
    cfg.balancer.strategy = Strategy::Semi;
    cfg.train.epochs = epochs;
    cfg.train.iters_per_epoch = iters;
    cfg.train.eval_iters = 2;
    cfg.train.lr = 0.01;
    cfg.train.train_batches = 16;
    // --threads N (0 = all cores): parallel rank execution; losses are
    // bitwise identical to --threads 1, only wall-clock drops.
    if let Some(t) = kv.get("threads") {
        cfg.train.threads = t.parse().expect("--threads");
    }
    // homogeneous first half, then a χ=2 straggler rotates in (paper's
    // dynamic heterogeneity): Fixed plan switched at the midpoint below.
    let mut t = Trainer::new(cfg)?;
    println!(
        "e2e: {} — {:.1}M params, e={} TP workers, bs={}, seq={}, threads={}",
        t.model().name,
        t.model().params_total as f64 / 1e6,
        t.model().e,
        t.model().bs,
        t.model().seq,
        t.threads(),
    );
    t.warmup_and_pretest()?;
    println!("warmup+pretest done; SEMI cost fit: Φ₁/col={:.2e}s Φ₂/col={:.2e}s",
             t.costs.phi1_per_col, t.costs.phi2_per_col);

    for epoch in 0..epochs {
        // straggler appears in the second half of the run
        t.cfg.stragglers = if epoch >= epochs / 2 {
            StragglerPlan::RoundRobin { chi: 2.0, period_epochs: 1 }
        } else {
            StragglerPlan::None
        };
        t.run_epoch(epoch)?;
        let e = t.report.epochs.last().unwrap();
        println!(
            "epoch {:>2} [{}]: RT(sim)={:.2}s wall={:.0}s loss={:.4} eval={:.4} acc={:.1}% pruned={} migrated={}",
            epoch,
            if epoch >= epochs / 2 { "χ=2 straggler" } else { "homogeneous " },
            e.rt_sim_s,
            e.rt_wall_s,
            e.train_loss,
            e.eval_loss,
            100.0 * e.acc,
            e.pruned_cols,
            e.migrated_cols,
        );
    }

    println!("\nloss curve ({} steps):", t.report.loss_curve.len());
    let curve = &t.report.loss_curve;
    for (i, chunk) in curve.chunks(8).enumerate() {
        let s: Vec<String> = chunk.iter().map(|l| format!("{l:.3}")).collect();
        println!("  steps {:>3}-{:>3}: {}", i * 8, i * 8 + chunk.len() - 1, s.join(" "));
    }
    let out = flextp::bench::out_dir().join("e2e_train.json");
    t.report.save_json(&out)?;
    println!("report: {} (loss curve + per-epoch RT/ACC)", out.display());

    // Success criterion: generalization improves over the run (per-step
    // train loss is noisy at this step count; eval is the signal).
    let eval0 = t.report.epochs.first().unwrap().eval_loss;
    let eval_best = t.report.epochs.iter().map(|e| e.eval_loss).fold(f64::INFINITY, f64::min);
    let acc_best = t.report.best_acc();
    println!("\neval loss: epoch0={eval0:.4} best={eval_best:.4}; best ACC={:.1}%",
             100.0 * acc_best);
    assert!(
        eval_best <= eval0 && acc_best > 1.5 / t.model().classes as f64,
        "no generalization improvement — end-to-end training is broken"
    );

    println!("\nper-executable timing profile (top 8):");
    for (name, calls, secs) in t.rt.timing_profile().into_iter().take(8) {
        println!("  {name:<24} {calls:>5} calls  {secs:>8.2}s total");
    }
    Ok(())
}
