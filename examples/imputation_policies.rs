//! Paper Fig. 3 in example form: how the imputation policy (Same /
//! Average / Zero) affects accuracy when every worker prunes at γ=0.5.
//!
//! Run: `cargo run --release --example imputation_policies`

use anyhow::Result;
use flextp::config::{Imputation, RunCfg, Strategy};
use flextp::train::trainer::Trainer;
use flextp::util::table::TextTable;

fn main() -> Result<()> {
    let mut table = TextTable::new(
        "imputation policies at uniform γ=0.5 (paper Fig. 3)",
        &["policy", "final ACC", "eval loss", "extra memory"],
    );
    for (policy, name) in [
        (Imputation::Same, "Same"),
        (Imputation::Average, "Average"),
        (Imputation::Zero, "Zero"),
    ] {
        let mut cfg = RunCfg::new("vit-tiny");
        cfg.balancer.strategy = Strategy::ZeroPri;
        cfg.balancer.imputation = policy;
        cfg.balancer.gamma_override = Some(0.5);
        cfg.train.epochs = 4;
        cfg.train.iters_per_epoch = 4;
        let mut t = Trainer::new(cfg)?;
        let r = t.run()?;
        // Same keeps a full previous-gradient copy per shard tensor —
        // the storage cost the paper rejects it for.
        let extra = match policy {
            Imputation::Same => "prev-grad copy per tensor",
            _ => "none",
        };
        table.row(&[
            name.to_string(),
            format!("{:.1}%", 100.0 * r.best_acc()),
            format!("{:.3}", r.final_eval_loss()),
            extra.to_string(),
        ]);
        println!("{}", r.summary());
    }
    println!("{}", table.render());
    println!("paper's choice: Zero — balances space complexity and accuracy (§III-A)");
    Ok(())
}
