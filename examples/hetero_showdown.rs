//! All seven balancing solutions side by side under one rotating straggler
//! (χ=4): the paper's compared-systems table in miniature.
//!
//! Run: `cargo run --release --example hetero_showdown [-- --model vit-s]`

use anyhow::Result;
use flextp::config::{parse_kv_args, RunCfg, StragglerPlan, Strategy};
use flextp::train::trainer::Trainer;
use flextp::util::table::TextTable;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (_, kv) = parse_kv_args(&args)?;
    let model = kv.get("model").map(String::as_str).unwrap_or("vit-tiny");
    let chi: f64 = kv.get("chi").map(|s| s.parse().unwrap()).unwrap_or(4.0);

    let strategies = [
        Strategy::Baseline,
        Strategy::ZeroRd,
        Strategy::ZeroPri,
        Strategy::ZeroPriDiffE,
        Strategy::ZeroPriDiffR,
        Strategy::Mig,
        Strategy::Semi,
    ];
    let mut reports = Vec::new();
    for s in strategies {
        let mut cfg = RunCfg::new(model);
        cfg.balancer.strategy = s;
        cfg.stragglers = StragglerPlan::RoundRobin { chi, period_epochs: 1 };
        cfg.train.epochs = 3;
        cfg.train.iters_per_epoch = 4;
        let mut t = Trainer::new(cfg)?;
        let r = t.run()?;
        println!("{}", r.summary());
        reports.push(r);
    }

    let base = reports[0].clone();
    let mut table = TextTable::new(
        &format!("hetero showdown: {model}, rotating straggler χ={chi}"),
        &["solution", "RT (s/epoch)", "speedup", "ACC", "ΔACC (pp)", "comm"],
    );
    for r in &reports {
        table.row(&[
            r.label.clone(),
            format!("{:.3}", r.rt()),
            format!("{:.2}x", flextp::bench::speedup(r, &base)),
            format!("{:.1}%", 100.0 * r.best_acc()),
            format!("{:+.1}", flextp::bench::acc_delta_pp(r, &base)),
            flextp::util::fmt_bytes(r.total_comm_bytes()),
        ]);
    }
    println!("{}", table.render());
    table.write_csv(&flextp::bench::out_dir().join("hetero_showdown.csv"))?;
    Ok(())
}
