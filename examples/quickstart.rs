//! Quickstart: train a tiny TP ViT twice — once as plain Colossal-AI-style
//! 1D tensor parallelism (Baseline) with a 4× straggler, once with the
//! paper's SEMI-migration hybrid — and compare RT/ACC.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use flextp::config::{RunCfg, StragglerPlan, Strategy};
use flextp::train::trainer::Trainer;
use flextp::util::table::TextTable;

fn run(strategy: Strategy) -> Result<flextp::metrics::RunReport> {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.balancer.strategy = strategy;
    cfg.stragglers = StragglerPlan::RoundRobin { chi: 4.0, period_epochs: 1 };
    cfg.train.epochs = 3;
    cfg.train.iters_per_epoch = 4;
    let mut t = Trainer::new(cfg)?;
    println!(
        "[{}] model={} params={} workers={}",
        strategy.name(),
        t.model().name,
        t.model().params_total,
        t.model().e
    );
    t.run()
}

fn main() -> Result<()> {
    let baseline = run(Strategy::Baseline)?;
    let semi = run(Strategy::Semi)?;

    let mut table = TextTable::new(
        "quickstart: one 4x straggler, rotating round-robin",
        &["solution", "RT (s/epoch, sim)", "final ACC", "speedup"],
    );
    for r in [&baseline, &semi] {
        table.row(&[
            r.label.clone(),
            format!("{:.3}", r.rt()),
            format!("{:.1}%", 100.0 * r.final_acc()),
            format!("{:.2}x", flextp::bench::speedup(r, &baseline)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "SEMI sheds the straggler's excess GEMM work via resizing+migration;\n\
         Baseline waits for it at every all-reduce (paper Fig. 10)."
    );
    Ok(())
}
