//! Leak probe: isolate which PJRT path retains memory per call.
use flextp::runtime::{Arg, Runtime};
use flextp::tensor::Tensor;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    let line = s.lines().find(|l| l.starts_with("VmRSS")).unwrap();
    line.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}

fn main() -> anyhow::Result<()> {
    let mode = std::env::args().nth(1).unwrap_or("literal".into());
    match mode.as_str() {
        "literal" => {
            // pure literal create+drop churn
            let data = vec![0u8; 1 << 20];
            println!("start rss={:.0}MB", rss_mb());
            for i in 0..2000 {
                let l = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32, &[256, 1024], &data)?;
                std::hint::black_box(&l);
                if i % 500 == 0 {
                    println!("iter {i}: rss={:.0}MB", rss_mb());
                }
            }
            println!("end rss={:.0}MB", rss_mb());
        }
        "exec" => {
            // probe the PJRT path explicitly — the native backend has no
            // device buffers to leak
            let dir = std::path::Path::new("artifacts/vit-tiny");
            let rt = Runtime::open(dir, "vit-tiny", flextp::config::BackendKind::Pjrt)?;
            let m = rt.manifest.model.clone();
            let patches = Tensor::zeros(&[m.bs, m.seq0, m.pd]);
            let w = Tensor::zeros(&[m.pd, m.hs]);
            let pos = Tensor::zeros(&[m.seq, m.hs]);
            let cls = Tensor::zeros(&[m.hs]);
            println!("start rss={:.0}MB", rss_mb());
            for i in 0..2000 {
                rt.call("embed_fwd", &[Arg::F32(&patches), Arg::F32(&w),
                                       Arg::F32(&pos), Arg::F32(&cls)])?;
                if i % 500 == 0 {
                    println!("iter {i}: rss={:.0}MB", rss_mb());
                }
            }
            println!("end rss={:.0}MB", rss_mb());
        }
        "raw" => {
            // execute + drop buffers, no literal conversion
            let client = xla::PjRtClient::cpu()?;
            let proto = xla::HloModuleProto::from_text_file(
                "artifacts/vit-tiny/embed_fwd.hlo.txt")?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let mk = |dims: &[usize]| {
                let n: usize = dims.iter().product();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32, dims, &vec![0u8; n * 4]).unwrap()
            };
            let args = [mk(&[8, 64, 48]), mk(&[48, 128]), mk(&[65, 128]), mk(&[128])];
            println!("start rss={:.0}MB", rss_mb());
            for i in 0..2000 {
                let out = exe.execute::<xla::Literal>(&args)?;
                std::hint::black_box(&out);
                drop(out);
                if i % 500 == 0 {
                    println!("iter {i}: rss={:.0}MB", rss_mb());
                }
            }
            println!("end rss={:.0}MB", rss_mb());
        }
        "tolit" => {
            // execute + to_literal_sync (no decompose)
            let client = xla::PjRtClient::cpu()?;
            let proto = xla::HloModuleProto::from_text_file(
                "artifacts/vit-tiny/embed_fwd.hlo.txt")?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let mk = |dims: &[usize]| {
                let n: usize = dims.iter().product();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32, dims, &vec![0u8; n * 4]).unwrap()
            };
            let args = [mk(&[8, 64, 48]), mk(&[48, 128]), mk(&[65, 128]), mk(&[128])];
            println!("start rss={:.0}MB", rss_mb());
            for i in 0..2000 {
                let out = exe.execute::<xla::Literal>(&args)?;
                let lit = out[0][0].to_literal_sync()?;
                std::hint::black_box(&lit);
                if i % 500 == 0 {
                    println!("iter {i}: rss={:.0}MB", rss_mb());
                }
            }
            println!("end rss={:.0}MB", rss_mb());
        }
        _ => {}
    }
    Ok(())
}
